//! Staged/live knob cells — the seam-only application mechanism.
//!
//! The Governor may *stage* a new value for any tunable knob at any
//! time (it runs between epochs on the consumer thread, but nothing
//! here assumes that); the staged values only become *live* when the
//! consumer crosses an epoch seam and [`TunedKnobs::commit`] runs.
//! Every reader on the hot path (workers, planner, credit gate, ring,
//! prefetch engine) sees exclusively the live cells, so a mid-epoch
//! stage can never perturb byte identity or the zero-alloc steady
//! state: the knob set is constant for the duration of an epoch by
//! construction.
//!
//! Components that hold their own tunable state (the [`CreditGate`]'s
//! credit window, the [`IoRing`]'s permit budget, the prefetch
//! engine's readahead depth) register *appliers* — closures invoked on
//! commit with the fresh live values. Workers and the planner instead
//! read the live atomics directly each loop iteration, which keeps the
//! read side lock-free and allocation-free.
//!
//! [`CreditGate`]: crate::dataloader::sampler::CreditGate
//! [`IoRing`]: crate::storage::IoRing

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::dataloader::DataloaderConfig;

/// One staged/live pair. Stages are written by the Governor, commits
/// copy staged → live, and the hot path loads live with relaxed
/// ordering (knob values are advisory rates/bounds, never used for
/// cross-thread happens-before).
struct Cell {
    staged: AtomicUsize,
    live: AtomicUsize,
}

impl Cell {
    fn new(v: usize) -> Cell {
        Cell { staged: AtomicUsize::new(v), live: AtomicUsize::new(v) }
    }

    fn stage(&self, v: usize) {
        self.staged.store(v, Ordering::Relaxed);
    }

    fn staged(&self) -> usize {
        self.staged.load(Ordering::Relaxed)
    }

    fn live(&self) -> usize {
        self.live.load(Ordering::Relaxed)
    }

    /// Returns true when the live value changed.
    fn commit(&self) -> bool {
        let v = self.staged.load(Ordering::Relaxed);
        self.live.swap(v, Ordering::Relaxed) != v
    }
}

/// The set of knobs the Governor may move at epoch seams, with their
/// staged (pending) and live (hot-path-visible) values.
pub struct TunedKnobs {
    /// consumer-credit window in batches (0 = unbounded)
    credit: Cell,
    /// prefetch engine readahead depth in items (0 = never speculate)
    prefetch_depth: Cell,
    /// I/O-ring in-flight read budget
    io_depth: Cell,
    /// workers allowed to pull new batches (injector mode only; the
    /// rest park and lend their arena slabs to the credit window)
    active_workers: Cell,
    /// item-granular stealing toggle (0/1)
    steal_items: Cell,
    /// cross-epoch plan publication depth
    epoch_pipeline: Cell,
    /// commit generation counter (one per epoch seam with the Governor
    /// attached; lets tests pin "knobs changed only at seams")
    commits: AtomicU64,
    /// ns workers spent parked because `active_workers` benched them
    throttled_ns: AtomicU64,
    /// seam appliers for components that keep their own tunable state
    appliers: Mutex<Vec<Box<dyn Fn(&TunedKnobs) + Send + Sync>>>,
    /// set once a Governor is steering; purely informational
    governed: AtomicBool,
}

impl TunedKnobs {
    /// Seed every knob from the loader configuration: live == staged ==
    /// the configured value, so an un-governed loader behaves exactly
    /// as before.
    pub fn from_config(cfg: &DataloaderConfig) -> Arc<TunedKnobs> {
        Arc::new(TunedKnobs {
            credit: Cell::new(cfg.consumer_credit),
            prefetch_depth: Cell::new(cfg.prefetch_depth),
            io_depth: Cell::new(cfg.io_depth),
            active_workers: Cell::new(cfg.num_workers),
            steal_items: Cell::new(cfg.steal_items as usize),
            epoch_pipeline: Cell::new(cfg.epoch_pipeline),
            commits: AtomicU64::new(0),
            throttled_ns: AtomicU64::new(0),
            appliers: Mutex::new(Vec::new()),
            governed: AtomicBool::new(false),
        })
    }

    // --- live reads (hot path) ---

    pub fn credit(&self) -> usize {
        self.credit.live()
    }

    pub fn prefetch_depth(&self) -> usize {
        self.prefetch_depth.live()
    }

    pub fn io_depth(&self) -> usize {
        self.io_depth.live()
    }

    pub fn active_workers(&self) -> usize {
        self.active_workers.live()
    }

    pub fn steal_items(&self) -> bool {
        self.steal_items.live() != 0
    }

    pub fn epoch_pipeline(&self) -> usize {
        self.epoch_pipeline.live()
    }

    // --- staged reads (the Governor's view of its own pending state) ---

    pub fn staged_credit(&self) -> usize {
        self.credit.staged()
    }

    pub fn staged_prefetch_depth(&self) -> usize {
        self.prefetch_depth.staged()
    }

    pub fn staged_io_depth(&self) -> usize {
        self.io_depth.staged()
    }

    pub fn staged_active_workers(&self) -> usize {
        self.active_workers.staged()
    }

    pub fn staged_steal_items(&self) -> bool {
        self.steal_items.staged() != 0
    }

    pub fn staged_epoch_pipeline(&self) -> usize {
        self.epoch_pipeline.staged()
    }

    // --- stages (Governor / stack assembler) ---

    pub fn stage_credit(&self, v: usize) {
        self.credit.stage(v);
    }

    pub fn stage_prefetch_depth(&self, v: usize) {
        self.prefetch_depth.stage(v);
    }

    pub fn stage_io_depth(&self, v: usize) {
        self.io_depth.stage(v);
    }

    pub fn stage_active_workers(&self, v: usize) {
        self.active_workers.stage(v);
    }

    pub fn stage_steal_items(&self, v: bool) {
        self.steal_items.stage(v as usize);
    }

    pub fn stage_epoch_pipeline(&self, v: usize) {
        self.epoch_pipeline.stage(v);
    }

    /// Register a seam applier: called (with the appliers lock held)
    /// after every commit that changed at least one live value, and
    /// once immediately so late-registered components sync up.
    pub fn register_applier(&self, f: Box<dyn Fn(&TunedKnobs) + Send + Sync>) {
        f(self);
        self.appliers.lock().unwrap().push(f);
    }

    /// Epoch-seam commit: copy every staged value into its live cell
    /// and run the appliers when anything moved. Called by
    /// `Dataloader::epoch` before the plan attach, so the whole next
    /// epoch — plan publication included — runs under the new values.
    /// Returns true when any live value changed.
    pub fn commit(&self) -> bool {
        self.commits.fetch_add(1, Ordering::Relaxed);
        let mut changed = self.credit.commit();
        changed |= self.prefetch_depth.commit();
        changed |= self.io_depth.commit();
        changed |= self.active_workers.commit();
        changed |= self.steal_items.commit();
        changed |= self.epoch_pipeline.commit();
        if changed {
            for f in self.appliers.lock().unwrap().iter() {
                f(self);
            }
        }
        changed
    }

    /// Seam commits performed so far.
    pub fn commit_count(&self) -> u64 {
        self.commits.load(Ordering::Relaxed)
    }

    /// Mark/query Governor attachment (informational; gates nothing).
    pub fn set_governed(&self) {
        self.governed.store(true, Ordering::Relaxed);
    }

    pub fn governed(&self) -> bool {
        self.governed.load(Ordering::Relaxed)
    }

    /// Book time a worker spent benched by `active_workers`.
    pub fn note_throttled(&self, d: std::time::Duration) {
        self.throttled_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn throttled(&self) -> std::time::Duration {
        std::time::Duration::from_nanos(self.throttled_ns.load(Ordering::Relaxed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stages_are_invisible_until_commit() {
        let cfg = DataloaderConfig { consumer_credit: 4, ..Default::default() };
        let k = TunedKnobs::from_config(&cfg);
        k.stage_credit(8);
        k.stage_steal_items(true);
        assert_eq!(k.credit(), 4);
        assert!(!k.steal_items());
        assert!(k.commit());
        assert_eq!(k.credit(), 8);
        assert!(k.steal_items());
        // idempotent: nothing staged since the last commit
        assert!(!k.commit());
    }

    #[test]
    fn appliers_run_on_registration_and_on_changing_commits() {
        let k = TunedKnobs::from_config(&DataloaderConfig::default());
        let seen = Arc::new(AtomicUsize::new(0));
        let s = seen.clone();
        k.register_applier(Box::new(move |knobs| {
            s.store(knobs.io_depth() + 1, Ordering::Relaxed);
        }));
        assert_eq!(seen.load(Ordering::Relaxed), 1); // sync-on-register
        k.stage_io_depth(32);
        assert!(k.commit());
        assert_eq!(seen.load(Ordering::Relaxed), 33);
        assert!(!k.commit()); // no change → appliers not re-run
    }
}
