//! Closed-loop autotuning: the Governor.
//!
//! The observability plane (PR 6) already measures where every epoch's
//! time goes — credit-blocked workers, seam idle, storage-wait vs
//! decode lane split, reorder high-water, ring queue depths, prefetch
//! tier hits. The Governor closes the loop: an online, hysteretic
//! hill-climber that reads those signals once per epoch and moves the
//! pipeline's tunable knobs (`consumer_credit`, `prefetch_depth`,
//! `io_depth`, effective worker parallelism, the steal/pipeline
//! toggles) in bounded steps, so the loader converges to a
//! per-storage-profile configuration nobody had to hand-sweep.
//!
//! ## Control loop
//!
//! ```text
//!  signals (per epoch)          decision               application
//!  ───────────────────          ────────               ───────────
//!  batches/s  ─┐                probe: stall attribution picks ONE
//!  p99 batch  ─┤  end_epoch →   knob + direction, stages a bounded
//!  stall lanes ┘                step (×2 / ÷2 along its ladder)
//!                               measure: the next epoch runs with the
//!                               staged value (committed at the seam)
//!                               keep/revert: keep only if batches/s
//!                               improved past the hysteresis margin
//!                               AND the p99 guard held; a revert puts
//!                               the knob on cooldown
//! ```
//!
//! Every stage only becomes visible at an epoch seam through
//! [`TunedKnobs::commit`] (called by `Dataloader::epoch` before the
//! plan attach), so mid-epoch byte identity and the zero-alloc steady
//! state are never disturbed — the knob set is constant within an
//! epoch by construction. The Governor itself is allocation-free after
//! construction: the decision log is a preallocated ring, metric
//! handles are pre-registered, and spans go through the lock-free
//! recorder.
//!
//! Stall attribution (rule order = priority):
//! 1. credit-blocked time dominates      → widen `consumer_credit`
//! 2. ring in-flight HWM at the budget   → raise `io_depth`
//! 3. prefetch tier missing demand       → deepen `prefetch_depth`
//! 4. seam idle with drained boundaries  → enable `epoch_pipeline`
//! 5. straggler tail (p99 ≫ mean, deep
//!    reorder buffer)                    → enable `steal_items`
//! 6. decode-bound with storage quiet    → bench workers
//!    (`active_workers` down: less contention on the decode lanes)
//! 7. otherwise                          → round-robin exploration
//!
//! Hard bounds come from [`KnobBounds`]: the credit window is capped by
//! the arena/slab budget (a wider window than the pool has slabs just
//! converts credit-blocked time into allocation fallbacks), pipelining
//! is locked for datasets without epoch-tagged loads, and the
//! worker-bench knob only exists under work-stealing dispatch (benched
//! round-robin queues would strand their batches).

pub mod knob;

pub use knob::TunedKnobs;

use std::sync::Arc;

use crate::dataloader::DataloaderConfig;
use crate::telemetry::{names, Metric, Recorder, GOVERNOR_WORKER};

/// The tunable knobs, as the Governor names them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Knob {
    Credit,
    PrefetchDepth,
    IoDepth,
    ActiveWorkers,
    StealItems,
    EpochPipeline,
}

impl Knob {
    pub fn label(&self) -> &'static str {
        match self {
            Knob::Credit => "consumer_credit",
            Knob::PrefetchDepth => "prefetch_depth",
            Knob::IoDepth => "io_depth",
            Knob::ActiveWorkers => "active_workers",
            Knob::StealItems => "steal_items",
            Knob::EpochPipeline => "epoch_pipeline",
        }
    }
}

/// Hard per-knob bounds. `None` locks a knob (the layer it steers is
/// not attached, or moving it is structurally unsafe). Bounds are
/// inclusive `(min, max)` in the knob's own unit.
#[derive(Debug, Clone, Copy)]
pub struct KnobBounds {
    pub credit: Option<(usize, usize)>,
    pub prefetch_depth: Option<(usize, usize)>,
    pub io_depth: Option<(usize, usize)>,
    pub active_workers: Option<(usize, usize)>,
    pub steal_items: bool,
    /// max publication depth (min is always 0 = drained)
    pub epoch_pipeline: Option<usize>,
}

impl KnobBounds {
    /// Everything locked — a Governor with these bounds observes but
    /// never probes.
    pub fn locked() -> KnobBounds {
        KnobBounds {
            credit: None,
            prefetch_depth: None,
            io_depth: None,
            active_workers: None,
            steal_items: false,
            epoch_pipeline: None,
        }
    }

    /// Derive bounds from the loader configuration and the attached
    /// stack layers. The credit cap comes from the arena budget:
    /// `arena_slabs − num_workers` (each worker can hold one slab in
    /// flight outside the reorder window); without an arena the
    /// reorder buffer is heap-backed and capped at `4 × workers`.
    pub fn derive(
        cfg: &DataloaderConfig,
        has_ring: bool,
        has_prefetch: bool,
        epoch_tagged: bool,
    ) -> KnobBounds {
        let w = cfg.num_workers;
        let credit = if w > 0 {
            let max = if cfg.arena_slabs > 0 {
                cfg.arena_slabs.saturating_sub(w).max(2)
            } else {
                (4 * w).max(2)
            };
            Some((2, max))
        } else {
            None
        };
        KnobBounds {
            credit,
            prefetch_depth: if has_prefetch {
                Some((4, cfg.prefetch_depth.max(256)))
            } else {
                None
            },
            io_depth: if has_ring {
                Some((4, cfg.io_depth.max(256)))
            } else {
                None
            },
            active_workers: if cfg.work_stealing && w > 1 {
                Some((1, w))
            } else {
                None
            },
            steal_items: cfg.work_stealing && cfg.arena_slabs > 0 && w > 0,
            epoch_pipeline: if epoch_tagged && w > 0 { Some(1) } else { None },
        }
    }
}

/// Per-epoch measurement fed to [`Governor::end_epoch`]. All values
/// are this epoch's deltas, not cumulative counters. `Copy` and
/// heap-free by design: building one in the epoch-end hook costs no
/// allocation.
#[derive(Debug, Clone, Copy, Default)]
pub struct Signals {
    pub epoch: usize,
    /// batches delivered this epoch
    pub batches: usize,
    /// wall time of the epoch (s)
    pub epoch_s: f64,
    /// p99 per-batch delivery time (s); 0 = not measured (guard off)
    pub p99_batch_s: f64,
    /// worker time blocked on the credit window (s)
    pub credit_blocked_s: f64,
    /// worker time parked at the epoch seam (s)
    pub seam_idle_s: f64,
    /// reorder-buffer high-water mark (batches)
    pub reorder_hwm: usize,
    /// items filled by non-owner workers
    pub item_steals: u64,
    /// storage lane time (s, summed over workers)
    pub storage_wait_s: f64,
    /// decode lane time (s, summed over workers)
    pub decode_s: f64,
    /// prefetch tier hit ratio in [0, 1]; negative = no prefetch layer
    pub prefetch_hit_ratio: f64,
    /// ring in-flight high-water mark this epoch
    pub ring_inflight_hwm: usize,
    /// ring ops still queued behind the permit budget at epoch end
    pub ring_queued: usize,
    /// heap allocations on the consumer thread this epoch
    pub allocs: u64,
    /// resilience-layer retries per logical storage op this epoch
    /// (retries / ops); 0 = no resilience layer or a quiet backend. A
    /// rising retry rate tells the hill-climber that widening `io_depth`
    /// or worker parallelism is amplifying pressure on a sick store.
    pub retry_rate: f64,
}

/// Hysteresis/settle parameters.
#[derive(Debug, Clone, Copy)]
pub struct GovernorConfig {
    /// epochs to observe before the first probe (baseline formation)
    pub warmup_epochs: usize,
    /// epochs a staged probe runs before the keep/revert verdict
    pub settle_epochs: usize,
    /// keep only if batches/s improves by more than this fraction
    pub keep_margin: f64,
    /// revert if p99 batch time degrades by more than this fraction
    pub p99_guard: f64,
    /// epochs a reverted knob sits out before it may probe again
    pub cooldown_epochs: usize,
}

impl Default for GovernorConfig {
    fn default() -> Self {
        GovernorConfig {
            warmup_epochs: 1,
            settle_epochs: 1,
            keep_margin: 0.03,
            p99_guard: 0.25,
            cooldown_epochs: 2,
        }
    }
}

/// What a control-loop step did.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// staged a trial value (takes effect at the next seam)
    Probe,
    /// trial beat the baseline past the margin with the p99 guard held
    Keep,
    /// trial failed; previous value restored, knob on cooldown
    Revert,
}

impl Action {
    pub fn label(&self) -> &'static str {
        match self {
            Action::Probe => "probe",
            Action::Keep => "keep",
            Action::Revert => "revert",
        }
    }
}

/// One entry of the decision log (preallocated ring; `Copy`).
#[derive(Debug, Clone, Copy)]
pub struct Decision {
    pub epoch: usize,
    pub knob: Knob,
    pub action: Action,
    pub from: usize,
    pub to: usize,
    /// objective at decision time (batches/s)
    pub bps: f64,
    /// p99 batch time at decision time (s)
    pub p99_s: f64,
}

/// Probe direction along a knob's value ladder.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Dir {
    Up,
    Down,
}

/// Per-knob hill-climb state: a bounded ladder of candidate values and
/// the current rung. `consumer_credit`'s ladder ends with 0
/// (unbounded) — the most permissive rung, one step past the arena
/// cap.
struct KnobState {
    kind: Knob,
    values: Vec<usize>,
    idx: usize,
    cooldown: usize,
}

impl KnobState {
    fn can(&self, dir: Dir) -> bool {
        self.cooldown == 0
            && match dir {
                Dir::Up => self.idx + 1 < self.values.len(),
                Dir::Down => self.idx > 0,
            }
    }

    fn value(&self) -> usize {
        self.values[self.idx]
    }
}

/// Geometric ladder `min, 2·min, … ≤ max` (max always included), with
/// `init` spliced in so the configured value is always a rung.
fn ladder(init: usize, min: usize, max: usize) -> Vec<usize> {
    let mut v = Vec::new();
    let mut x = min.max(1);
    while x < max {
        v.push(x);
        x = x.saturating_mul(2);
    }
    v.push(max);
    if init >= min && init <= max && !v.contains(&init) {
        v.push(init);
    }
    v.sort_unstable();
    v.dedup();
    v
}

fn nearest_idx(values: &[usize], init: usize) -> usize {
    values
        .iter()
        .position(|&v| v >= init)
        .unwrap_or(values.len().saturating_sub(1))
}

#[derive(Clone, Copy)]
enum Phase {
    Warmup { left: usize },
    Idle,
    Probe { state: usize, prev_idx: usize, settle_left: usize },
}

/// Pre-registered metric handles (`governor.*` in the hub) — cached
/// `Arc<Metric>`s so the per-epoch step touches no hub lock.
struct Gauges {
    steps: Arc<Metric>,
    probes: Arc<Metric>,
    keeps: Arc<Metric>,
    reverts: Arc<Metric>,
    bps_x1000: Arc<Metric>,
    baseline_bps_x1000: Arc<Metric>,
    credit: Arc<Metric>,
    prefetch_depth: Arc<Metric>,
    io_depth: Arc<Metric>,
    active_workers: Arc<Metric>,
    steal_items: Arc<Metric>,
    epoch_pipeline: Arc<Metric>,
}

const DECISION_LOG_CAP: usize = 256;

/// The online autotuner. One per pipeline; drive it with
/// [`Governor::end_epoch`] once per finished epoch (the rig and
/// `cdl run --autotune` wire this into the trainer's epoch-end hook).
pub struct Governor {
    cfg: GovernorConfig,
    knobs: Arc<TunedKnobs>,
    states: Vec<KnobState>,
    phase: Phase,
    baseline_bps: f64,
    baseline_p99: f64,
    rr_cursor: usize,
    epochs_seen: u64,
    probes: u64,
    keeps: u64,
    reverts: u64,
    /// decision ring: preallocated, overwrites oldest past the cap
    decisions: Vec<Decision>,
    decision_head: usize,
    decisions_total: u64,
    recorder: Option<Arc<Recorder>>,
    gauges: Option<Gauges>,
}

impl Governor {
    pub fn new(
        cfg: GovernorConfig,
        knobs: Arc<TunedKnobs>,
        bounds: KnobBounds,
    ) -> Governor {
        knobs.set_governed();
        let mut states = Vec::new();
        if let Some((min, max)) = bounds.credit {
            // most permissive rung last: 0 = unbounded window
            let mut values = ladder(knobs.credit(), min, max);
            values.push(0);
            let init = knobs.credit();
            let idx = if init == 0 {
                values.len() - 1
            } else {
                nearest_idx(&values[..values.len() - 1], init)
            };
            states.push(KnobState { kind: Knob::Credit, values, idx, cooldown: 0 });
        }
        if let Some((min, max)) = bounds.prefetch_depth {
            let values = ladder(knobs.prefetch_depth(), min, max);
            let idx = nearest_idx(&values, knobs.prefetch_depth());
            states.push(KnobState {
                kind: Knob::PrefetchDepth,
                values,
                idx,
                cooldown: 0,
            });
        }
        if let Some((min, max)) = bounds.io_depth {
            let values = ladder(knobs.io_depth(), min, max);
            let idx = nearest_idx(&values, knobs.io_depth());
            states.push(KnobState { kind: Knob::IoDepth, values, idx, cooldown: 0 });
        }
        if let Some((min, max)) = bounds.active_workers {
            let values: Vec<usize> = (min..=max).collect();
            let idx = nearest_idx(&values, knobs.active_workers());
            states.push(KnobState {
                kind: Knob::ActiveWorkers,
                values,
                idx,
                cooldown: 0,
            });
        }
        if bounds.steal_items {
            let idx = knobs.steal_items() as usize;
            states.push(KnobState {
                kind: Knob::StealItems,
                values: vec![0, 1],
                idx,
                cooldown: 0,
            });
        }
        if let Some(max) = bounds.epoch_pipeline {
            let values: Vec<usize> = (0..=max.max(1)).collect();
            let idx = nearest_idx(&values, knobs.epoch_pipeline());
            states.push(KnobState {
                kind: Knob::EpochPipeline,
                values,
                idx,
                cooldown: 0,
            });
        }
        Governor {
            cfg,
            knobs,
            states,
            phase: Phase::Warmup { left: cfg.warmup_epochs.max(1) },
            baseline_bps: 0.0,
            baseline_p99: 0.0,
            rr_cursor: 0,
            epochs_seen: 0,
            probes: 0,
            keeps: 0,
            reverts: 0,
            decisions: Vec::with_capacity(DECISION_LOG_CAP),
            decision_head: 0,
            decisions_total: 0,
            recorder: None,
            gauges: None,
        }
    }

    /// Attach the telemetry plane: decision spans on the Governor track
    /// of the Chrome trace, `governor.*` counters/gauges in the hub
    /// (handles pre-registered here so the step path stays
    /// allocation-free).
    pub fn with_recorder(mut self, rec: Arc<Recorder>) -> Governor {
        let hub = rec.metrics();
        self.gauges = Some(Gauges {
            steps: hub.metric("governor.steps"),
            probes: hub.metric("governor.probes"),
            keeps: hub.metric("governor.keeps"),
            reverts: hub.metric("governor.reverts"),
            bps_x1000: hub.metric("governor.bps_x1000"),
            baseline_bps_x1000: hub.metric("governor.baseline_bps_x1000"),
            credit: hub.metric("governor.knob.consumer_credit"),
            prefetch_depth: hub.metric("governor.knob.prefetch_depth"),
            io_depth: hub.metric("governor.knob.io_depth"),
            active_workers: hub.metric("governor.knob.active_workers"),
            steal_items: hub.metric("governor.knob.steal_items"),
            epoch_pipeline: hub.metric("governor.knob.epoch_pipeline"),
        });
        self.recorder = Some(rec);
        self
    }

    pub fn knobs(&self) -> &Arc<TunedKnobs> {
        &self.knobs
    }

    /// `(baseline batches/s, baseline p99 s)` of the current plateau.
    pub fn baseline(&self) -> (f64, f64) {
        (self.baseline_bps, self.baseline_p99)
    }

    pub fn counts(&self) -> (u64, u64, u64) {
        (self.probes, self.keeps, self.reverts)
    }

    pub fn phase_label(&self) -> &'static str {
        match self.phase {
            Phase::Warmup { .. } => "warmup",
            Phase::Idle => "idle",
            Phase::Probe { .. } => "probe",
        }
    }

    /// Decision log in chronological order (allocates; snapshot path
    /// only — the hot loop never calls this).
    pub fn decisions(&self) -> Vec<Decision> {
        let n = self.decisions.len();
        let mut out = Vec::with_capacity(n);
        if n == DECISION_LOG_CAP {
            out.extend_from_slice(&self.decisions[self.decision_head..]);
            out.extend_from_slice(&self.decisions[..self.decision_head]);
        } else {
            out.extend_from_slice(&self.decisions);
        }
        out
    }

    fn log(&mut self, d: Decision) {
        if self.decisions.len() < DECISION_LOG_CAP {
            self.decisions.push(d);
        } else {
            self.decisions[self.decision_head] = d;
            self.decision_head = (self.decision_head + 1) % DECISION_LOG_CAP;
        }
        self.decisions_total += 1;
    }

    fn stage(knobs: &TunedKnobs, kind: Knob, v: usize) {
        match kind {
            Knob::Credit => knobs.stage_credit(v),
            Knob::PrefetchDepth => knobs.stage_prefetch_depth(v),
            Knob::IoDepth => knobs.stage_io_depth(v),
            Knob::ActiveWorkers => knobs.stage_active_workers(v),
            Knob::StealItems => knobs.stage_steal_items(v != 0),
            Knob::EpochPipeline => knobs.stage_epoch_pipeline(v),
        }
    }

    /// Stall attribution: pick the knob and direction the signals blame
    /// most. Falls back to round-robin exploration (up preferred) so
    /// plateaus still get probed.
    fn pick(&mut self, sig: &Signals) -> Option<(usize, Dir)> {
        let epoch_s = sig.epoch_s.max(1e-9);
        let mean_batch = epoch_s / sig.batches.max(1) as f64;
        let find = |states: &[KnobState], kind: Knob, dir: Dir| -> Option<usize> {
            states
                .iter()
                .position(|s| s.kind == kind)
                .filter(|&i| states[i].can(dir))
        };
        // 1. credit-blocked → widen the window
        if sig.credit_blocked_s > 0.05 * epoch_s {
            if let Some(i) = find(&self.states, Knob::Credit, Dir::Up) {
                return Some((i, Dir::Up));
            }
        }
        // 2. ring budget saturated → deepen it
        if sig.ring_inflight_hwm * 10 >= self.knobs.io_depth().max(1) * 9
            || sig.ring_queued > 0
        {
            if let Some(i) = find(&self.states, Knob::IoDepth, Dir::Up) {
                return Some((i, Dir::Up));
            }
        }
        // 3. prefetch tier missing demand → deepen the horizon
        if sig.prefetch_hit_ratio >= 0.0 && sig.prefetch_hit_ratio < 0.85 {
            if let Some(i) = find(&self.states, Knob::PrefetchDepth, Dir::Up) {
                return Some((i, Dir::Up));
            }
        }
        // 4. workers idle at drained seams → pipeline the boundary
        if sig.seam_idle_s > 0.03 * epoch_s && self.knobs.epoch_pipeline() == 0 {
            if let Some(i) = find(&self.states, Knob::EpochPipeline, Dir::Up) {
                return Some((i, Dir::Up));
            }
        }
        // 5. straggler tail → item-granular stealing
        if !self.knobs.steal_items()
            && (sig.p99_batch_s > 3.0 * mean_batch || sig.reorder_hwm >= 4)
        {
            if let Some(i) = find(&self.states, Knob::StealItems, Dir::Up) {
                return Some((i, Dir::Up));
            }
        }
        // 6. decode-bound, storage quiet → bench a worker
        if sig.decode_s > 4.0 * sig.storage_wait_s && sig.decode_s > 0.0 {
            if let Some(i) = find(&self.states, Knob::ActiveWorkers, Dir::Down) {
                return Some((i, Dir::Down));
            }
        }
        // 7. exploration: round-robin over whatever can still move
        for off in 0..self.states.len() {
            let i = (self.rr_cursor + off) % self.states.len();
            for dir in [Dir::Up, Dir::Down] {
                if self.states[i].can(dir) {
                    self.rr_cursor = (i + 1) % self.states.len();
                    return Some((i, dir));
                }
            }
        }
        None
    }

    fn start_probe(&mut self, sig: &Signals, bps: f64) {
        let Some((i, dir)) = self.pick(sig) else {
            self.phase = Phase::Idle;
            return;
        };
        let st = &mut self.states[i];
        let prev_idx = st.idx;
        st.idx = match dir {
            Dir::Up => st.idx + 1,
            Dir::Down => st.idx - 1,
        };
        let (kind, from, to) = (st.kind, st.values[prev_idx], st.value());
        Self::stage(&self.knobs, kind, to);
        self.probes += 1;
        self.log(Decision {
            epoch: sig.epoch,
            knob: kind,
            action: Action::Probe,
            from,
            to,
            bps,
            p99_s: sig.p99_batch_s,
        });
        self.phase = Phase::Probe {
            state: i,
            prev_idx,
            settle_left: self.cfg.settle_epochs.max(1),
        };
    }

    /// One control-loop step: feed the finished epoch's signals,
    /// receive (via the staged knob cells) at most one bounded change
    /// for the next epoch. Allocation-free after construction.
    pub fn end_epoch(&mut self, sig: &Signals) {
        let t0 = self.recorder.as_ref().map(|r| r.now());
        self.epochs_seen += 1;
        let bps = sig.batches as f64 / sig.epoch_s.max(1e-9);
        for st in &mut self.states {
            st.cooldown = st.cooldown.saturating_sub(1);
        }
        match self.phase {
            Phase::Warmup { left } => {
                self.baseline_bps = bps;
                self.baseline_p99 = sig.p99_batch_s;
                if left > 1 {
                    self.phase = Phase::Warmup { left: left - 1 };
                } else {
                    self.start_probe(sig, bps);
                }
            }
            Phase::Idle => {
                // drift the baseline with the plateau
                self.baseline_bps = 0.5 * self.baseline_bps + 0.5 * bps;
                if sig.p99_batch_s > 0.0 {
                    self.baseline_p99 = 0.5 * self.baseline_p99 + 0.5 * sig.p99_batch_s;
                }
                self.start_probe(sig, bps);
            }
            Phase::Probe { state, prev_idx, settle_left } => {
                if settle_left > 1 {
                    self.phase = Phase::Probe {
                        state,
                        prev_idx,
                        settle_left: settle_left - 1,
                    };
                } else {
                    let improved = bps > self.baseline_bps * (1.0 + self.cfg.keep_margin);
                    let p99_ok = self.baseline_p99 <= 0.0
                        || sig.p99_batch_s <= 0.0
                        || sig.p99_batch_s
                            <= self.baseline_p99 * (1.0 + self.cfg.p99_guard);
                    let st = &mut self.states[state];
                    if improved && p99_ok {
                        let (kind, from, to) =
                            (st.kind, st.values[prev_idx], st.value());
                        self.baseline_bps = bps;
                        if sig.p99_batch_s > 0.0 {
                            self.baseline_p99 = sig.p99_batch_s;
                        }
                        self.keeps += 1;
                        self.log(Decision {
                            epoch: sig.epoch,
                            knob: kind,
                            action: Action::Keep,
                            from,
                            to,
                            bps,
                            p99_s: sig.p99_batch_s,
                        });
                    } else {
                        let (kind, from) = (st.kind, st.value());
                        st.idx = prev_idx;
                        st.cooldown = self.cfg.cooldown_epochs;
                        let to = st.value();
                        Self::stage(&self.knobs, kind, to);
                        self.reverts += 1;
                        self.log(Decision {
                            epoch: sig.epoch,
                            knob: kind,
                            action: Action::Revert,
                            from,
                            to,
                            bps,
                            p99_s: sig.p99_batch_s,
                        });
                    }
                    self.start_probe(sig, bps);
                }
            }
        }
        if let Some(g) = &self.gauges {
            g.steps.inc();
            g.probes.set(self.probes);
            g.keeps.set(self.keeps);
            g.reverts.set(self.reverts);
            g.bps_x1000.set((bps * 1000.0) as u64);
            g.baseline_bps_x1000.set((self.baseline_bps * 1000.0) as u64);
            g.credit.set(self.knobs.staged_credit() as u64);
            g.prefetch_depth.set(self.knobs.staged_prefetch_depth() as u64);
            g.io_depth.set(self.knobs.staged_io_depth() as u64);
            g.active_workers.set(self.knobs.staged_active_workers() as u64);
            g.steal_items.set(self.knobs.staged_steal_items() as u64);
            g.epoch_pipeline.set(self.knobs.staged_epoch_pipeline() as u64);
        }
        if let (Some(rec), Some(t0)) = (&self.recorder, t0) {
            rec.record_tagged(
                names::GOVERNOR_STEP,
                GOVERNOR_WORKER,
                self.decisions_total as i64,
                sig.epoch as i64,
                (bps * 1000.0) as i64,
                t0,
                rec.now(),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn knobs() -> Arc<TunedKnobs> {
        TunedKnobs::from_config(&DataloaderConfig {
            num_workers: 4,
            arena_slabs: 16,
            work_stealing: true,
            consumer_credit: 4,
            io_depth: 8,
            prefetch_depth: 8,
            ..Default::default()
        })
    }

    #[test]
    fn ladder_includes_bounds_and_init() {
        assert_eq!(ladder(6, 2, 16), vec![2, 4, 6, 8, 16]);
        assert_eq!(ladder(2, 2, 2), vec![2]);
        assert_eq!(ladder(0, 4, 64), vec![4, 8, 16, 32, 64]);
    }

    #[test]
    fn probe_stages_but_live_waits_for_commit() {
        let k = knobs();
        let mut gov = Governor::new(
            GovernorConfig::default(),
            k.clone(),
            KnobBounds {
                credit: Some((2, 12)),
                prefetch_depth: None,
                io_depth: None,
                active_workers: None,
                steal_items: false,
                epoch_pipeline: None,
            },
        );
        // warmup epoch, then a credit-blocked epoch attributes to credit
        let sig = Signals {
            batches: 10,
            epoch_s: 1.0,
            credit_blocked_s: 0.5,
            ..Default::default()
        };
        gov.end_epoch(&sig); // warmup → probes immediately after baseline
        assert_eq!(gov.counts().0, 1, "one probe staged");
        assert_eq!(k.staged_credit(), 8, "credit widened 4 → 8");
        assert_eq!(k.credit(), 4, "live untouched until the seam commit");
        k.commit();
        assert_eq!(k.credit(), 8);
    }

    #[test]
    fn keep_and_revert_move_the_baseline_and_cooldown() {
        let k = knobs();
        let mut gov = Governor::new(
            GovernorConfig { cooldown_epochs: 3, ..Default::default() },
            k.clone(),
            KnobBounds {
                credit: Some((2, 12)),
                prefetch_depth: None,
                io_depth: None,
                active_workers: None,
                steal_items: false,
                epoch_pipeline: None,
            },
        );
        let blocked = |bps: f64| Signals {
            batches: 100,
            epoch_s: 100.0 / bps,
            credit_blocked_s: 0.5 * 100.0 / bps,
            ..Default::default()
        };
        gov.end_epoch(&blocked(10.0)); // warmup + probe 4→8
        k.commit();
        gov.end_epoch(&blocked(12.0)); // +20% → keep, probe 8→12
        assert_eq!(gov.counts().1, 1, "kept");
        k.commit();
        gov.end_epoch(&blocked(12.1)); // < margin → revert to 8
        assert_eq!(gov.counts().2, 1, "reverted");
        assert_eq!(k.staged_credit(), 8);
        // knob on cooldown: the next pick finds nothing else to move
        // (only credit is tunable), so no probe starts
        let before = gov.counts().0;
        gov.end_epoch(&blocked(12.0));
        assert_eq!(gov.counts().0, before, "cooldown blocks re-probe");
    }
}
