//! `artifacts/manifest.json` parsing — the contract between `aot.py`
//! (which writes it) and the rust runtime (which loads artifacts and
//! asserts smoke numbers from it).

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::{self, Json};

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    doc: Json,
}

/// One model parameter (name + shape, in flattening order).
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

/// Train-step smoke numbers (expected losses on the example batch).
#[derive(Debug, Clone)]
pub struct Smoke {
    pub variant: String,
    pub batch: usize,
    pub image: usize,
    pub losses: Vec<f64>,
    pub rtol: f64,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {path:?}"))?;
        Ok(Manifest { doc: json::parse(&text)? })
    }

    pub fn from_str(text: &str) -> Result<Manifest> {
        Ok(Manifest { doc: json::parse(text)? })
    }

    pub fn num_params(&self) -> usize {
        self.doc
            .at(&["model", "num_params"])
            .and_then(Json::as_usize)
            .unwrap_or(0)
    }

    pub fn num_classes(&self) -> usize {
        self.doc
            .at(&["model", "num_classes"])
            .and_then(Json::as_usize)
            .unwrap_or(0)
    }

    /// Number of parameter tensors.
    pub fn param_count(&self) -> usize {
        self.param_specs().map(|v| v.len()).unwrap_or(0)
    }

    pub fn param_specs(&self) -> Option<Vec<ParamSpec>> {
        let arr = self.doc.at(&["model", "params"])?.as_arr()?;
        let mut out = Vec::with_capacity(arr.len());
        for p in arr {
            out.push(ParamSpec {
                name: p.get("name")?.as_str()?.to_string(),
                shape: p
                    .get("shape")?
                    .as_arr()?
                    .iter()
                    .filter_map(Json::as_usize)
                    .collect(),
            });
        }
        Some(out)
    }

    /// File name of an artifact by logical name.
    pub fn artifact_file(&self, name: &str) -> Option<String> {
        self.doc
            .at(&["artifacts", name, "file"])
            .and_then(Json::as_str)
            .map(str::to_string)
    }

    pub fn artifact_names(&self) -> Vec<String> {
        self.doc
            .at(&["artifacts"])
            .and_then(Json::as_obj)
            .map(|m| m.keys().cloned().collect())
            .unwrap_or_default()
    }

    /// (batch, image) of a train/forward variant.
    pub fn variant_shape(&self, name: &str) -> Option<(usize, usize)> {
        let b = self.doc.at(&["artifacts", name, "batch"])?.as_usize()?;
        let i = self.doc.at(&["artifacts", name, "image"])?.as_usize()?;
        Some((b, i))
    }

    /// Pick the train_step variant matching (batch, image).
    pub fn train_variant(&self, batch: usize, image: usize) -> Result<String> {
        let name = format!("train_step_b{batch}_i{image}");
        self.artifact_file(&name)
            .map(|_| name.clone())
            .ok_or_else(|| {
                anyhow!(
                    "no artifact {name}; available: {:?}",
                    self.artifact_names()
                )
            })
    }

    pub fn smoke(&self) -> Option<Smoke> {
        let s = self.doc.get("smoke")?;
        Some(Smoke {
            variant: s.get("variant")?.as_str()?.to_string(),
            batch: s.get("batch")?.as_usize()?,
            image: s.get("image")?.as_usize()?,
            losses: s
                .get("losses")?
                .as_arr()?
                .iter()
                .filter_map(Json::as_f64)
                .collect(),
            rtol: s.get("rtol").and_then(Json::as_f64).unwrap_or(1e-4),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "model": {
            "num_params": 100,
            "num_classes": 512,
            "params": [
                {"name": "stem/w", "shape": [3, 3, 3, 32]},
                {"name": "stem/b", "shape": [32]}
            ]
        },
        "artifacts": {
            "init": {"file": "init.hlo.txt"},
            "train_step_b8_i32": {"file": "train_step_b8_i32.hlo.txt", "batch": 8, "image": 32}
        },
        "smoke": {"variant": "train_step_b8_i32", "batch": 8, "image": 32,
                  "losses": [6.2, 5.9], "rtol": 0.001}
    }"#;

    #[test]
    fn parses_model_block() {
        let m = Manifest::from_str(SAMPLE).unwrap();
        assert_eq!(m.num_params(), 100);
        assert_eq!(m.num_classes(), 512);
        let specs = m.param_specs().unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].name, "stem/w");
        assert_eq!(specs[0].shape, vec![3, 3, 3, 32]);
        assert_eq!(m.param_count(), 2);
    }

    #[test]
    fn artifact_lookup() {
        let m = Manifest::from_str(SAMPLE).unwrap();
        assert_eq!(m.artifact_file("init").unwrap(), "init.hlo.txt");
        assert!(m.artifact_file("nope").is_none());
        assert_eq!(m.variant_shape("train_step_b8_i32").unwrap(), (8, 32));
        assert_eq!(m.train_variant(8, 32).unwrap(), "train_step_b8_i32");
        assert!(m.train_variant(99, 99).is_err());
    }

    #[test]
    fn smoke_block() {
        let m = Manifest::from_str(SAMPLE).unwrap();
        let s = m.smoke().unwrap();
        assert_eq!(s.losses, vec![6.2, 5.9]);
        assert_eq!(s.rtol, 0.001);
    }
}
