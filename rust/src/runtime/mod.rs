//! PJRT runtime: load AOT HLO-text artifacts (produced by
//! `python/compile/aot.py`) and execute them from the rust hot path.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), so all XLA
//! state lives on one dedicated **engine thread**; callers talk to it
//! through a channel with plain byte payloads ([`XlaEngine`]). Parameters
//! stay resident on the engine thread between steps — only the batch
//! crosses the channel.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`), never
//! serialized protos — see DESIGN.md and aot.py for the version gotcha.

pub mod manifest;

pub use manifest::Manifest;

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

/// Element types crossing the engine channel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    U8,
    I32,
    F32,
}

impl Dtype {
    fn element_type(&self) -> xla::ElementType {
        match self {
            Dtype::U8 => xla::ElementType::U8,
            Dtype::I32 => xla::ElementType::S32,
            Dtype::F32 => xla::ElementType::F32,
        }
    }

    pub fn size(&self) -> usize {
        match self {
            Dtype::U8 => 1,
            Dtype::I32 | Dtype::F32 => 4,
        }
    }
}

/// A host-side tensor argument (raw little-endian bytes).
#[derive(Debug, Clone)]
pub struct HostTensor {
    pub dtype: Dtype,
    pub dims: Vec<usize>,
    pub bytes: Vec<u8>,
}

impl HostTensor {
    pub fn from_u8(dims: &[usize], data: Vec<u8>) -> HostTensor {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        HostTensor { dtype: Dtype::U8, dims: dims.to_vec(), bytes: data }
    }

    pub fn from_i32(dims: &[usize], data: &[i32]) -> HostTensor {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        let mut bytes = Vec::with_capacity(data.len() * 4);
        for v in data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        HostTensor { dtype: Dtype::I32, dims: dims.to_vec(), bytes }
    }

    pub fn from_f32(dims: &[usize], data: &[f32]) -> HostTensor {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        let mut bytes = Vec::with_capacity(data.len() * 4);
        for v in data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        HostTensor { dtype: Dtype::F32, dims: dims.to_vec(), bytes }
    }

    pub fn to_f32_vec(&self) -> Vec<f32> {
        assert_eq!(self.dtype, Dtype::F32);
        self.bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }
}

enum Request {
    /// Compile an artifact (idempotent).
    Load { name: String },
    /// Run init.hlo.txt and hold the resulting params on-thread.
    InitParams,
    /// Load explicit params (testing / checkpoint restore).
    SetParams { tensors: Vec<HostTensor> },
    /// Get a copy of the resident params.
    GetParams,
    /// One train step on the resident params; returns the loss.
    TrainStep { variant: String, images: HostTensor, labels: HostTensor },
    /// Forward pass with resident params; returns logits.
    Forward { variant: String, images: HostTensor },
    /// Raw artifact execution (kernel cross-checks): returns all outputs.
    Run { name: String, inputs: Vec<HostTensor> },
    Shutdown,
}

enum Response {
    Unit,
    Loss(f32),
    Tensors(Vec<HostTensor>),
}

struct Envelope {
    req: Request,
    reply: mpsc::Sender<Result<Response>>,
}

/// Handle to the engine thread.
pub struct XlaEngine {
    tx: Mutex<mpsc::Sender<Envelope>>,
    handle: Option<std::thread::JoinHandle<()>>,
    manifest: Manifest,
}

impl XlaEngine {
    /// Start the engine over an artifacts directory (with manifest.json).
    pub fn start(artifacts_dir: impl Into<PathBuf>) -> Result<XlaEngine> {
        let dir: PathBuf = artifacts_dir.into();
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {dir:?} (run `make artifacts`)"))?;
        let (tx, rx) = mpsc::channel::<Envelope>();
        let man = manifest.clone();
        let handle = std::thread::Builder::new()
            .name("xla-engine".into())
            .spawn(move || engine_thread(dir, man, rx))
            .expect("spawn xla engine");
        Ok(XlaEngine { tx: Mutex::new(tx), handle: Some(handle), manifest })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn call(&self, req: Request) -> Result<Response> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Envelope { req, reply: rtx })
            .map_err(|_| anyhow!("xla engine thread gone"))?;
        rrx.recv().map_err(|_| anyhow!("xla engine dropped reply"))?
    }

    /// Pre-compile an artifact.
    pub fn load(&self, name: &str) -> Result<()> {
        self.call(Request::Load { name: name.to_string() }).map(|_| ())
    }

    /// Initialize resident params via init.hlo.txt.
    pub fn init_params(&self) -> Result<()> {
        self.call(Request::InitParams).map(|_| ())
    }

    pub fn set_params(&self, tensors: Vec<HostTensor>) -> Result<()> {
        self.call(Request::SetParams { tensors }).map(|_| ())
    }

    pub fn get_params(&self) -> Result<Vec<HostTensor>> {
        match self.call(Request::GetParams)? {
            Response::Tensors(t) => Ok(t),
            _ => bail!("unexpected response"),
        }
    }

    /// Run one fused train step (params update in place); returns loss.
    pub fn train_step(
        &self,
        variant: &str,
        images: HostTensor,
        labels: HostTensor,
    ) -> Result<f32> {
        match self.call(Request::TrainStep {
            variant: variant.to_string(),
            images,
            labels,
        })? {
            Response::Loss(l) => Ok(l),
            _ => bail!("unexpected response"),
        }
    }

    /// Forward pass; returns logits as a flat f32 tensor.
    pub fn forward(&self, variant: &str, images: HostTensor) -> Result<HostTensor> {
        match self.call(Request::Forward { variant: variant.to_string(), images })? {
            Response::Tensors(mut t) => {
                t.pop().ok_or_else(|| anyhow!("no logits output"))
            }
            _ => bail!("unexpected response"),
        }
    }

    /// Execute any artifact on explicit inputs (kernel cross-checks).
    pub fn run(&self, name: &str, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        match self.call(Request::Run { name: name.to_string(), inputs })? {
            Response::Tensors(t) => Ok(t),
            _ => bail!("unexpected response"),
        }
    }
}

impl Drop for XlaEngine {
    fn drop(&mut self) {
        let _ = self.call(Request::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Engine thread
// ---------------------------------------------------------------------------

struct Engine {
    dir: PathBuf,
    manifest: Manifest,
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    /// resident model params (as literals, fed back each step)
    params: Vec<xla::Literal>,
}

fn engine_thread(dir: PathBuf, manifest: Manifest, rx: mpsc::Receiver<Envelope>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            // fail every request with the construction error
            while let Ok(env) = rx.recv() {
                let _ = env.reply.send(Err(anyhow!("PJRT client failed: {e}")));
            }
            return;
        }
    };
    let mut eng = Engine {
        dir,
        manifest,
        client,
        exes: HashMap::new(),
        params: Vec::new(),
    };
    while let Ok(env) = rx.recv() {
        if matches!(env.req, Request::Shutdown) {
            let _ = env.reply.send(Ok(Response::Unit));
            break;
        }
        let out = eng.handle(env.req);
        let _ = env.reply.send(out);
    }
}

impl Engine {
    fn exe(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.exes.contains_key(name) {
            let file = self
                .manifest
                .artifact_file(name)
                .ok_or_else(|| anyhow!("unknown artifact {name}"))?;
            let path = self.dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parse {path:?}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {name}: {e}"))?;
            self.exes.insert(name.to_string(), exe);
        }
        Ok(&self.exes[name])
    }

    fn to_literal(t: &HostTensor) -> Result<xla::Literal> {
        xla::Literal::create_from_shape_and_untyped_data(
            t.dtype.element_type(),
            &t.dims,
            &t.bytes,
        )
        .map_err(|e| anyhow!("literal: {e}"))
    }

    fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape().map_err(|e| anyhow!("shape: {e}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|d| *d as usize).collect();
        let (dtype, len) = match shape.ty() {
            xla::ElementType::U8 => (Dtype::U8, lit.element_count()),
            xla::ElementType::S32 => (Dtype::I32, lit.element_count() * 4),
            xla::ElementType::F32 => (Dtype::F32, lit.element_count() * 4),
            other => bail!("unsupported output type {other:?}"),
        };
        let mut bytes = vec![0u8; len];
        match dtype {
            Dtype::U8 => lit
                .copy_raw_to::<u8>(&mut bytes)
                .map_err(|e| anyhow!("copy u8: {e}"))?,
            Dtype::I32 => {
                let mut tmp = vec![0i32; lit.element_count()];
                lit.copy_raw_to::<i32>(&mut tmp)
                    .map_err(|e| anyhow!("copy i32: {e}"))?;
                for (i, v) in tmp.iter().enumerate() {
                    bytes[i * 4..(i + 1) * 4].copy_from_slice(&v.to_le_bytes());
                }
            }
            Dtype::F32 => {
                let mut tmp = vec![0f32; lit.element_count()];
                lit.copy_raw_to::<f32>(&mut tmp)
                    .map_err(|e| anyhow!("copy f32: {e}"))?;
                for (i, v) in tmp.iter().enumerate() {
                    bytes[i * 4..(i + 1) * 4].copy_from_slice(&v.to_le_bytes());
                }
            }
        }
        Ok(HostTensor { dtype, dims, bytes })
    }

    /// Execute `name` with literals; returns the decomposed output tuple.
    fn execute(&mut self, name: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.exe(name)?;
        let result = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow!("execute {name}: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e}"))?;
        // aot.py lowers with return_tuple=True
        lit.to_tuple().map_err(|e| anyhow!("untuple: {e}"))
    }

    fn handle(&mut self, req: Request) -> Result<Response> {
        match req {
            Request::Load { name } => {
                self.exe(&name)?;
                Ok(Response::Unit)
            }
            Request::InitParams => {
                let outs = self.execute("init", &[])?;
                if outs.len() != self.manifest.param_count() {
                    bail!(
                        "init produced {} params, manifest says {}",
                        outs.len(),
                        self.manifest.param_count()
                    );
                }
                self.params = outs;
                Ok(Response::Unit)
            }
            Request::SetParams { tensors } => {
                self.params = tensors
                    .iter()
                    .map(Self::to_literal)
                    .collect::<Result<Vec<_>>>()?;
                Ok(Response::Unit)
            }
            Request::GetParams => {
                let out = self
                    .params
                    .iter()
                    .map(Self::from_literal)
                    .collect::<Result<Vec<_>>>()?;
                Ok(Response::Tensors(out))
            }
            Request::TrainStep { variant, images, labels } => {
                if self.params.is_empty() {
                    bail!("params not initialized (call init_params)");
                }
                let mut args: Vec<xla::Literal> = self.params.clone();
                args.push(Self::to_literal(&images)?);
                args.push(Self::to_literal(&labels)?);
                let mut outs = self.execute(&variant, &args)?;
                let loss_lit = outs.pop().ok_or_else(|| anyhow!("empty outputs"))?;
                if outs.len() != self.params.len() {
                    bail!(
                        "train step returned {} params, expected {}",
                        outs.len(),
                        self.params.len()
                    );
                }
                self.params = outs;
                let loss = loss_lit
                    .get_first_element::<f32>()
                    .map_err(|e| anyhow!("loss: {e}"))?;
                Ok(Response::Loss(loss))
            }
            Request::Forward { variant, images } => {
                if self.params.is_empty() {
                    bail!("params not initialized");
                }
                let mut args: Vec<xla::Literal> = self.params.clone();
                args.push(Self::to_literal(&images)?);
                let outs = self.execute(&variant, &args)?;
                Ok(Response::Tensors(
                    outs.iter().map(Self::from_literal).collect::<Result<_>>()?,
                ))
            }
            Request::Run { name, inputs } => {
                let args: Vec<xla::Literal> = inputs
                    .iter()
                    .map(Self::to_literal)
                    .collect::<Result<Vec<_>>>()?;
                let outs = self.execute(&name, &args)?;
                Ok(Response::Tensors(
                    outs.iter().map(Self::from_literal).collect::<Result<_>>()?,
                ))
            }
            Request::Shutdown => Ok(Response::Unit),
        }
    }
}

/// The deterministic example batch of `model.make_example_batch` —
/// bit-identical to the python side (Knuth-hash pattern).
pub fn example_batch(batch: usize, img: usize, num_classes: usize) -> (HostTensor, HostTensor) {
    let n = batch * img * img * 3;
    let data: Vec<u8> = (0..n)
        .map(|i| ((i as u32).wrapping_mul(2654435761) % 256) as u8)
        .collect();
    let labels: Vec<i32> = (0..batch).map(|i| ((i * 7) % num_classes) as i32).collect();
    (
        HostTensor::from_u8(&[batch, img, img, 3], data),
        HostTensor::from_i32(&[batch], &labels),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_roundtrips() {
        let t = HostTensor::from_f32(&[2, 2], &[1.0, -2.5, 3.25, 0.0]);
        assert_eq!(t.to_f32_vec(), vec![1.0, -2.5, 3.25, 0.0]);
        let t = HostTensor::from_i32(&[3], &[1, -7, 42]);
        assert_eq!(t.bytes.len(), 12);
    }

    #[test]
    #[should_panic]
    fn host_tensor_checks_dims() {
        HostTensor::from_f32(&[2, 3], &[0.0; 5]);
    }

    #[test]
    fn example_batch_matches_python_pattern() {
        let (imgs, labels) = example_batch(2, 8, 512);
        assert_eq!(imgs.dims, vec![2, 8, 8, 3]);
        for i in [0usize, 1, 17, 100] {
            let want = ((i as u64 * 2654435761) % (1 << 32) % 256) as u8;
            assert_eq!(imgs.bytes[i], want);
        }
        assert_eq!(labels.dims, vec![2]);
    }

    // engine-level tests live in rust/tests/test_runtime.rs (they need
    // built artifacts)
}
