//! Telemetry plane — the "Measured activities" lane of the paper's Fig 1,
//! rebuilt as an always-on observability subsystem.
//!
//! Every instrumented activity (`get_batch`, `get_item`,
//! `training_batch_to_device`, `run_training_batch`, the Lightning lanes,
//! worker spawns…) is recorded as a [`Span`] with worker id, batch id, the
//! owning ticket's `(epoch, seq)` tags and a start/end pair on a shared
//! monotonic clock. Reports derive medians (Fig 14), timelines
//! (Fig 2/17/19), fade-in/out histograms (Fig 23) and the Table 3
//! GPU-utilization aggregates from the same recorder.
//!
//! The plane has four parts:
//!
//! * [`ring`] (re-exported here) — the lock-free [`Recorder`]: sharded
//!   fixed-capacity ring buffers with claim-index writes. No Mutex, no
//!   allocation after construction, cheap enough to leave enabled during
//!   the zero-alloc steady state (`tests/test_alloc.rs` asserts this).
//! * [`metrics`] — the unified [`MetricsHub`]: one registry of named
//!   atomic counters/gauges absorbing the scattered pipeline signals
//!   (reorder high-water, item steals, seam idle, credit-block time,
//!   cache/prefetch/arena/alloc stats), snapshotted per epoch as JSON.
//! * [`chrome`] — Chrome `trace_event` export (`cdl run --trace out.json`,
//!   loadable in Perfetto) with planner/worker/consumer named tracks and
//!   epoch seams as instant events.
//! * [`baseline`] — CI-gated bench baselines (`cdl reproduce hotpath
//!   --baseline BENCH_hotpath.json --check`).

pub mod baseline;
pub mod chrome;
mod metrics;
mod ring;

pub use metrics::{Metric, MetricsHub};
pub use ring::{Recorder, Span, DEFAULT_SPAN_CAPACITY};

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use crate::util::stats;

/// Standard span names (the paper's measurement points).
pub mod names {
    pub const GET_BATCH: &str = "get_batch"; // next_data wait
    pub const BATCH_INFLIGHT: &str = "batch_inflight"; // fetch start → queued
    pub const GET_ITEM: &str = "get_item"; // Dataset __getitem__
    pub const TO_DEVICE: &str = "training_batch_to_device";
    pub const TRAIN_BATCH: &str = "run_training_batch";
    pub const OPTIMIZER_STEP: &str = "optimizer_step";
    pub const WORKER_SPAWN: &str = "worker_spawn";
    pub const PIN_MEMORY: &str = "pin_memory";
    /// background GET issued by the prefetch engine
    pub const PREFETCH_FETCH: &str = "prefetch_fetch";
    /// demand lookup that waited on an in-flight prefetch
    pub const PREFETCH_WAIT: &str = "prefetch_wait";
    /// planner computed + published one epoch plan
    pub const PLAN_PUBLISH: &str = "plan_publish";
    /// planner unpublished mispredicted speculative plans (value =
    /// tickets withdrawn from the sink)
    pub const PLAN_REVOKE: &str = "plan_revoke";
    /// one submitted I/O batch, submit → last completion reaped
    pub const RING_BATCH: &str = "ring_batch";
    /// instant marker: the consumer crossed an epoch boundary
    pub const EPOCH_SEAM: &str = "epoch_seam";
    /// one Governor control-loop step: signals in → probe/keep/revert out
    pub const GOVERNOR_STEP: &str = "governor_step";
    // resilience plane (chaos-ready storage)
    /// one backoff-retry wait before re-driving a failed read
    pub const RETRY: &str = "retry";
    /// a speculative duplicate read launched past the online p95
    pub const HEDGE: &str = "hedge";
    /// circuit-breaker event: a trip or an open-state fast-fail
    pub const BREAKER: &str = "breaker";
    // Lightning lanes (Fig 17)
    pub const ADVANCE: &str = "advance";
    pub const PRERUN: &str = "prerun";
    pub const NEXT_DATA: &str = "next_data";
    pub const PREP_TRAINING: &str = "prep_training";
    pub const POSTRUN: &str = "postrun";
}

/// Synthetic worker id used for planner-thread spans (the planner runs
/// on whichever worker crosses the seam first, so a stable synthetic id
/// keeps its spans on one named track).
pub const PLANNER_WORKER: u32 = u32::MAX - 1;

/// Synthetic worker id for I/O-ring batch spans (`names::RING_BATCH`):
/// submissions come from many worker threads but multiplex through one
/// ring executor, so they share one named track.
pub const RING_WORKER: u32 = u32::MAX - 2;

/// Synthetic worker id for Governor decision spans
/// (`names::GOVERNOR_STEP`): the autotuner runs at epoch seams on the
/// consumer thread but its control-loop steps get their own track.
pub const GOVERNOR_WORKER: u32 = u32::MAX - 3;

/// Synthetic worker id for resilience-layer spans (`names::RETRY`,
/// `names::HEDGE`, `names::BREAKER`): retries and hedges fire from ring
/// executor tasks and blocking fetch threads alike, so they share one
/// named track.
pub const RESILIENCE_WORKER: u32 = u32::MAX - 4;

// ---------------------------------------------------------------------------
// GPU utilization sampling (Table 3 metrics)
// ---------------------------------------------------------------------------

/// Shared gauges exported by the simulated device.
#[derive(Debug, Default)]
pub struct DeviceGauges {
    /// busy-compute flag ⇒ util sample in percent ×100 (0 if idle)
    pub util_x100: AtomicU64,
    /// memory utilization in percent ×100
    pub mem_x100: AtomicU64,
}

/// One 10 Hz utilization sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct UtilSample {
    pub t: f64,
    pub util: f64,
    pub mem: f64,
}

/// Sidecar sampler thread at `hz` (paper: 10 Hz).
pub struct UtilSampler {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<Vec<UtilSample>>>,
}

impl UtilSampler {
    pub fn start(rec: Arc<Recorder>, gauges: Arc<DeviceGauges>, hz: f64) -> UtilSampler {
        let stop = Arc::new(AtomicBool::new(false));
        let st = stop.clone();
        let period = std::time::Duration::from_secs_f64(1.0 / hz);
        let handle = std::thread::Builder::new()
            .name("util-sampler".into())
            .spawn(move || {
                let mut samples = Vec::new();
                while !st.load(Ordering::Relaxed) {
                    samples.push(UtilSample {
                        t: rec.now(),
                        util: gauges.util_x100.load(Ordering::Relaxed) as f64 / 100.0,
                        mem: gauges.mem_x100.load(Ordering::Relaxed) as f64 / 100.0,
                    });
                    std::thread::sleep(period);
                }
                samples
            })
            .expect("spawn util sampler");
        UtilSampler { stop, handle: Some(handle) }
    }

    pub fn stop(mut self) -> Vec<UtilSample> {
        self.stop.store(true, Ordering::Relaxed);
        self.handle.take().map(|h| h.join().unwrap()).unwrap_or_default()
    }
}

/// Table 3 aggregate: (util=0 %, mean util>0 %, mem=0 %, mean mem>0 %).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct UtilAggregate {
    pub util_zero_pct: f64,
    pub util_nonzero_mean: f64,
    pub mem_zero_pct: f64,
    pub mem_nonzero_mean: f64,
}

pub fn aggregate_util(samples: &[UtilSample]) -> UtilAggregate {
    let agg = |vals: Vec<f64>| -> (f64, f64) {
        if vals.is_empty() {
            return (f64::NAN, f64::NAN);
        }
        let zero = vals.iter().filter(|v| **v <= 0.0).count();
        let nonzero: Vec<f64> = vals.iter().copied().filter(|v| *v > 0.0).collect();
        (
            100.0 * zero as f64 / vals.len() as f64,
            stats::mean(&nonzero),
        )
    };
    let (uz, um) = agg(samples.iter().map(|s| s.util).collect());
    let (mz, mm) = agg(samples.iter().map(|s| s.mem).collect());
    UtilAggregate {
        util_zero_pct: uz,
        util_nonzero_mean: um,
        mem_zero_pct: mz,
        mem_nonzero_mean: mm,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn util_sampler_and_aggregate() {
        let rec = Recorder::new();
        let gauges = Arc::new(DeviceGauges::default());
        let sampler = UtilSampler::start(rec, gauges.clone(), 100.0);
        std::thread::sleep(std::time::Duration::from_millis(50));
        gauges.util_x100.store(7200, Ordering::Relaxed);
        gauges.mem_x100.store(4000, Ordering::Relaxed);
        std::thread::sleep(std::time::Duration::from_millis(50));
        let samples = sampler.stop();
        assert!(samples.len() >= 5);
        let agg = aggregate_util(&samples);
        assert!(agg.util_zero_pct > 10.0 && agg.util_zero_pct < 90.0);
        assert!((agg.util_nonzero_mean - 72.0).abs() < 1.0);
    }

    #[test]
    fn aggregate_empty_is_nan() {
        let a = aggregate_util(&[]);
        assert!(a.util_zero_pct.is_nan());
    }
}
