//! Chrome `trace_event` export: turn a recorder snapshot into a JSON
//! document loadable in Perfetto / `chrome://tracing`.
//!
//! Spans are laid out on named tracks — `consumer` (tid 0), `planner`
//! (tid 1), `prefetch` (tid 2) and one `worker N` track per worker
//! (tid 10+N) — as `ph:"X"` duration events with `(batch, epoch, seq)`
//! in `args`. Epoch seams ([`names::EPOCH_SEAM`]) become global instant
//! events (`ph:"i"`, `s:"g"`), so the cross-epoch pipeline's overlap is
//! visible at a glance. Timestamps are recorder seconds scaled to the
//! format's microseconds.

use std::collections::BTreeSet;

use super::{names, Span};
use crate::util::json::Json;

/// Synthetic pid for the single-process trace.
const PID: u64 = 1;

const TID_CONSUMER: u64 = 0;
const TID_PLANNER: u64 = 1;
const TID_PREFETCH: u64 = 2;
const TID_WORKER_BASE: u64 = 10;

/// Track assignment: consumer-side lanes by name, planner/prefetch by
/// name, everything else (`batch_inflight`, `get_item`, `worker_spawn`)
/// on its recording worker's track.
fn tid(span: &Span) -> u64 {
    match span.name {
        names::GET_BATCH
        | names::PIN_MEMORY
        | names::TO_DEVICE
        | names::TRAIN_BATCH
        | names::OPTIMIZER_STEP
        | names::EPOCH_SEAM
        | names::ADVANCE
        | names::PRERUN
        | names::NEXT_DATA
        | names::PREP_TRAINING
        | names::POSTRUN => TID_CONSUMER,
        names::PLAN_PUBLISH => TID_PLANNER,
        names::PREFETCH_FETCH | names::PREFETCH_WAIT => TID_PREFETCH,
        _ => TID_WORKER_BASE + span.worker as u64,
    }
}

fn track_name(tid: u64) -> String {
    match tid {
        TID_CONSUMER => "consumer".to_string(),
        TID_PLANNER => "planner".to_string(),
        TID_PREFETCH => "prefetch".to_string(),
        // synthetic workers get their own readable tracks
        t => match (t - TID_WORKER_BASE) as u32 {
            super::PLANNER_WORKER => "planner aux".to_string(),
            super::RING_WORKER => "io_ring".to_string(),
            super::GOVERNOR_WORKER => "governor".to_string(),
            super::RESILIENCE_WORKER => "resilience".to_string(),
            w => format!("worker {w}"),
        },
    }
}

fn metadata(name: &str, tid: Option<u64>, label: &str) -> Json {
    let mut args = Json::obj();
    args.set("name", label);
    let mut ev = Json::obj();
    ev.set("args", args).set("name", name).set("ph", "M").set("pid", PID);
    if let Some(t) = tid {
        ev.set("tid", t);
    }
    ev
}

/// Render spans (a [`super::Recorder::snapshot`]) as a Chrome
/// `trace_event` document.
pub fn chrome_trace(spans: &[Span]) -> Json {
    let mut events: Vec<Json> = Vec::new();
    events.push(metadata("process_name", None, "cdl"));
    let tids: BTreeSet<u64> = spans.iter().map(tid).collect();
    for t in &tids {
        events.push(metadata("thread_name", Some(*t), &track_name(*t)));
        // order tracks consumer → planner → prefetch → workers
        let mut args = Json::obj();
        args.set("sort_index", *t);
        let mut ev = Json::obj();
        ev.set("args", args)
            .set("name", "thread_sort_index")
            .set("ph", "M")
            .set("pid", PID)
            .set("tid", *t);
        events.push(ev);
    }
    for s in spans {
        let mut args = Json::obj();
        args.set("batch", s.batch).set("epoch", s.epoch).set("seq", s.seq);
        let mut ev = Json::obj();
        ev.set("args", args)
            .set("name", s.name)
            .set("pid", PID)
            .set("tid", tid(s))
            .set("ts", (s.t0 * 1e6).round());
        if s.name == names::EPOCH_SEAM {
            ev.set("ph", "i").set("s", "g");
        } else {
            ev.set("ph", "X").set("dur", (s.duration().max(0.0) * 1e6).round());
        }
        events.push(ev);
    }
    let mut doc = Json::obj();
    doc.set("displayTimeUnit", "ms").set("traceEvents", Json::Arr(events));
    doc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn span(name: &'static str, worker: u32, batch: i64, epoch: i64, seq: i64, t0: f64, t1: f64) -> Span {
        Span { name, worker, batch, epoch, seq, t0, t1 }
    }

    #[test]
    fn tracks_are_named_and_events_typed() {
        let spans = vec![
            span(names::PLAN_PUBLISH, u32::MAX - 1, -1, 0, 0, 0.0, 0.001),
            span(names::BATCH_INFLIGHT, 2, 5, 0, 5, 0.01, 0.03),
            span(names::GET_BATCH, 0, 5, 0, 5, 0.02, 0.031),
            span(names::EPOCH_SEAM, 0, -1, 1, -1, 0.05, 0.05),
        ];
        let doc = chrome_trace(&spans);
        let text = doc.to_string();
        let parsed = json::parse(&text).unwrap();
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // 1 process_name + 3 tracks × 2 metadata + 4 span events
        assert_eq!(events.len(), 11);
        let names_of = |ph: &str| -> Vec<&str> {
            events
                .iter()
                .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some(ph))
                .filter_map(|e| e.get("name").and_then(|n| n.as_str()))
                .collect()
        };
        assert_eq!(names_of("X"), vec!["plan_publish", "batch_inflight", "get_batch"]);
        assert_eq!(names_of("i"), vec!["epoch_seam"]);
        let labels: Vec<&str> = events
            .iter()
            .filter(|e| e.get("name").and_then(|n| n.as_str()) == Some("thread_name"))
            .filter_map(|e| e.at(&["args", "name"]).and_then(|n| n.as_str()))
            .collect();
        assert_eq!(labels, vec!["consumer", "planner", "worker 2"]);
    }

    #[test]
    fn golden_duration_event() {
        let spans = vec![span(names::GET_ITEM, 1, 7, 2, 19, 0.5, 0.75)];
        let doc = chrome_trace(&spans);
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // keys sort as args,dur,name,ph,pid,tid,ts — stable golden form
        assert_eq!(
            events.last().unwrap().to_string(),
            r#"{"args":{"batch":7,"epoch":2,"seq":19},"dur":250000,"name":"get_item","ph":"X","pid":1,"tid":11,"ts":500000}"#
        );
    }

    #[test]
    fn empty_snapshot_still_parses() {
        let doc = chrome_trace(&[]);
        let parsed = json::parse(&doc.to_string()).unwrap();
        assert_eq!(parsed.get("traceEvents").unwrap().as_arr().unwrap().len(), 1);
    }
}
