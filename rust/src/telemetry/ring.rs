//! Lock-free span recording: sharded fixed-capacity ring buffers with
//! claim-index writes and seqlock-style slot stamps.
//!
//! The old recorder was a contended `Mutex<Vec<Span>>` that had to stay
//! disabled on the zero-alloc hot path; this one is cheap enough to
//! leave on. One `record` is a relaxed `fetch_add` (ticket claim), one
//! CAS (slot claim), a 64-byte volatile write and a release store — no
//! Mutex, no heap allocation after construction.
//!
//! Concurrency protocol, per shard:
//!
//! * a writer claims a monotonically increasing ticket `i` via
//!   `fetch_add` on the shard cursor; its slot is `i % capacity`;
//! * the slot stamp encodes state: `0` = never written, `2k+1` = write
//!   of ticket `k` in progress, `2k+2` = ticket `k` stable. The writer
//!   CASes the current (even, older) stamp to `2i+1`, writes the
//!   payload, then publishes `2i+2` with a release store;
//! * if the stamp is odd (a lapped writer is still mid-write) or the CAS
//!   fails, the span is **dropped** — counted in [`Recorder::dropped`] —
//!   instead of torn;
//! * a reader accepts a slot only if the stamp reads the same stable
//!   ticket before *and* after the payload copy (seqlock read), so a
//!   snapshot taken concurrently with writers never observes torn spans.
//!
//! Threads are spread over shards by a thread-local shard hint (const
//! initialised — no lazy TLS allocation), so concurrent workers do not
//! contend on one cursor cache line.

use std::cell::{Cell, UnsafeCell};
use std::sync::atomic::{fence, AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use super::metrics::MetricsHub;
use crate::util::stats;
use crate::util::table::Table;

/// One recorded activity interval on the recorder clock.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    pub name: &'static str,
    pub worker: u32,
    pub batch: i64,
    /// epoch of the owning ticket (-1 when not epoch-scoped)
    pub epoch: i64,
    /// global pipeline sequence of the owning ticket (-1 when unknown)
    pub seq: i64,
    /// start/end seconds on the recorder clock
    pub t0: f64,
    pub t1: f64,
}

impl Span {
    pub fn duration(&self) -> f64 {
        self.t1 - self.t0
    }
}

/// Fixed-size payload stored in a ring slot (one cache line).
#[derive(Clone, Copy)]
struct SpanData {
    name: &'static str,
    worker: u32,
    batch: i64,
    epoch: i64,
    seq: i64,
    t0: f64,
    t1: f64,
}

const EMPTY: SpanData =
    SpanData { name: "", worker: 0, batch: 0, epoch: 0, seq: 0, t0: 0.0, t1: 0.0 };

#[inline]
fn wip(ticket: u64) -> u64 {
    2 * ticket + 1
}

#[inline]
fn stable(ticket: u64) -> u64 {
    2 * ticket + 2
}

struct Slot {
    stamp: AtomicU64,
    data: UnsafeCell<SpanData>,
}

// Safety: `data` is only written between a successful claim CAS on
// `stamp` (odd, "in progress") and the release store of the stable
// stamp; readers validate the stamp before and after the volatile copy
// and discard torn reads.
unsafe impl Sync for Slot {}

struct Shard {
    cursor: AtomicU64,
    dropped: AtomicU64,
    slots: Box<[Slot]>,
}

impl Shard {
    fn with_slots(n: usize) -> Shard {
        let slots: Vec<Slot> = (0..n)
            .map(|_| Slot { stamp: AtomicU64::new(0), data: UnsafeCell::new(EMPTY) })
            .collect();
        Shard {
            cursor: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            slots: slots.into_boxed_slice(),
        }
    }

    fn push(&self, d: SpanData) {
        let cap = self.slots.len() as u64;
        let ticket = self.cursor.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(ticket % cap) as usize];
        let cur = slot.stamp.load(Ordering::Relaxed);
        // odd = a lapped writer is still inside this slot; >= our wip =
        // an even faster lap already claimed past us. Either way the
        // ring has wrapped a full capacity mid-write: drop, never tear.
        if cur % 2 == 1
            || cur >= wip(ticket)
            || slot
                .stamp
                .compare_exchange(cur, wip(ticket), Ordering::Acquire, Ordering::Relaxed)
                .is_err()
        {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        unsafe { std::ptr::write_volatile(slot.data.get(), d) };
        slot.stamp.store(stable(ticket), Ordering::Release);
    }

    fn collect(&self, out: &mut Vec<Span>) {
        let cap = self.slots.len() as u64;
        let n = self.cursor.load(Ordering::Acquire);
        for ticket in n.saturating_sub(cap)..n {
            let slot = &self.slots[(ticket % cap) as usize];
            if slot.stamp.load(Ordering::Acquire) != stable(ticket) {
                continue;
            }
            let d = unsafe { std::ptr::read_volatile(slot.data.get()) };
            fence(Ordering::Acquire);
            if slot.stamp.load(Ordering::Relaxed) != stable(ticket) {
                continue; // overwritten mid-copy: discard the torn read
            }
            out.push(Span {
                name: d.name,
                worker: d.worker,
                batch: d.batch,
                epoch: d.epoch,
                seq: d.seq,
                t0: d.t0,
                t1: d.t1,
            });
        }
    }

    fn retained(&self) -> usize {
        let n = self.cursor.load(Ordering::Relaxed);
        let dropped = self.dropped.load(Ordering::Relaxed);
        (n.min(self.slots.len() as u64).saturating_sub(dropped.min(n))) as usize
    }

    fn reset(&self) {
        self.cursor.store(0, Ordering::Relaxed);
        self.dropped.store(0, Ordering::Relaxed);
        for slot in self.slots.iter() {
            slot.stamp.store(0, Ordering::Relaxed);
        }
    }
}

/// Total spans retained across all shards by default (override with the
/// `CDL_SPAN_CAPACITY` env var or the `span_capacity` config knob).
pub const DEFAULT_SPAN_CAPACITY: usize = 65_536;

const SHARDS: usize = 8;

std::thread_local! {
    // const-init Cell: no lazy TLS initialisation, no allocation, no
    // destructor — safe to touch inside the zero-alloc window.
    static SHARD_HINT: Cell<usize> = const { Cell::new(usize::MAX) };
}

static NEXT_SHARD: AtomicUsize = AtomicUsize::new(0);

fn shard_hint() -> usize {
    SHARD_HINT.with(|c| {
        let mut v = c.get();
        if v == usize::MAX {
            v = NEXT_SHARD.fetch_add(1, Ordering::Relaxed);
            c.set(v);
        }
        v
    })
}

/// Thread-safe lock-free span recorder with a shared origin clock and
/// the process-wide [`MetricsHub`] attached.
pub struct Recorder {
    origin: Instant,
    enabled: AtomicBool,
    shards: Box<[Shard]>,
    metrics: MetricsHub,
}

impl Recorder {
    pub fn new() -> Arc<Recorder> {
        let cap = std::env::var("CDL_SPAN_CAPACITY")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(DEFAULT_SPAN_CAPACITY);
        Recorder::with_capacity(cap)
    }

    /// `capacity` = total retained spans across all shards, rounded up
    /// to a shard multiple; the ring overwrites the oldest spans once
    /// full. 0 selects [`DEFAULT_SPAN_CAPACITY`].
    pub fn with_capacity(capacity: usize) -> Arc<Recorder> {
        let capacity = if capacity == 0 { DEFAULT_SPAN_CAPACITY } else { capacity };
        let per_shard = capacity.max(SHARDS).div_ceil(SHARDS);
        let shards: Vec<Shard> = (0..SHARDS).map(|_| Shard::with_slots(per_shard)).collect();
        Arc::new(Recorder {
            origin: Instant::now(),
            enabled: AtomicBool::new(true),
            shards: shards.into_boxed_slice(),
            metrics: MetricsHub::new(),
        })
    }

    /// Seconds since recorder creation.
    pub fn now(&self) -> f64 {
        self.origin.elapsed().as_secs_f64()
    }

    /// The unified metrics registry riding on this recorder: everything
    /// holding the recorder can publish counters without extra plumbing.
    pub fn metrics(&self) -> &MetricsHub {
        &self.metrics
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    pub fn record(&self, name: &'static str, worker: u32, batch: i64, t0: f64, t1: f64) {
        self.record_tagged(name, worker, batch, -1, -1, t0, t1);
    }

    /// Record a span carrying the owning ticket's `(epoch, seq)` so the
    /// cross-epoch ticket stream stays attributable end to end.
    #[allow(clippy::too_many_arguments)]
    pub fn record_tagged(
        &self,
        name: &'static str,
        worker: u32,
        batch: i64,
        epoch: i64,
        seq: i64,
        t0: f64,
        t1: f64,
    ) {
        if !self.enabled.load(Ordering::Relaxed) {
            return;
        }
        let shard = &self.shards[shard_hint() % self.shards.len()];
        shard.push(SpanData { name, worker, batch, epoch, seq, t0, t1 });
    }

    /// Time a closure as a span.
    pub fn time<T>(
        &self,
        name: &'static str,
        worker: u32,
        batch: i64,
        f: impl FnOnce() -> T,
    ) -> T {
        let t0 = self.now();
        let out = f();
        self.record(name, worker, batch, t0, self.now());
        out
    }

    /// Retained span count (approximate while writers are active).
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.retained()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Spans discarded because the ring lapped a writer mid-write.
    pub fn dropped(&self) -> u64 {
        self.shards.iter().map(|s| s.dropped.load(Ordering::Relaxed)).sum()
    }

    /// Total ring capacity in spans.
    pub fn capacity(&self) -> usize {
        self.shards.iter().map(|s| s.slots.len()).sum()
    }

    /// Snapshot all retained spans (sorted by start time). Safe against
    /// concurrent writers: torn slots are skipped, never mangled.
    pub fn snapshot(&self) -> Vec<Span> {
        let mut v = Vec::with_capacity(self.len());
        for shard in self.shards.iter() {
            shard.collect(&mut v);
        }
        v.sort_by(|a, b| a.t0.total_cmp(&b.t0));
        v
    }

    /// Reset all rings. Callers must be quiescent (no concurrent
    /// `record`), as with any ring restart.
    pub fn clear(&self) {
        for shard in self.shards.iter() {
            shard.reset();
        }
    }

    /// Durations of all spans with the given name.
    pub fn durations(&self, name: &str) -> Vec<f64> {
        self.snapshot()
            .iter()
            .filter(|s| s.name == name)
            .map(|s| s.duration())
            .collect()
    }

    pub fn median(&self, name: &str) -> f64 {
        stats::median(&self.durations(name))
    }

    /// Per-name summary table (Fig 14-style medians).
    pub fn summary_table(&self, title: &str) -> Table {
        use std::collections::BTreeMap;
        let mut by_name: BTreeMap<&str, Vec<f64>> = BTreeMap::new();
        for s in self.snapshot() {
            by_name.entry(s.name).or_default().push(s.duration());
        }
        let mut t = Table::new(
            title,
            &["span", "count", "median_s", "mean_s", "p90_s", "max_s"],
        );
        for (name, durs) in by_name {
            let s = stats::Summary::of(&durs);
            t.row(&[
                name.to_string(),
                s.count.to_string(),
                format!("{:.4}", s.p50),
                format!("{:.4}", s.mean),
                format!("{:.4}", s.p90),
                format!("{:.4}", s.max),
            ]);
        }
        t
    }

    /// CSV export of the raw timeline (Fig 2 / Fig 17 data).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("name,worker,batch,epoch,seq,t0,t1,duration\n");
        for s in self.snapshot() {
            out.push_str(&format!(
                "{},{},{},{},{},{:.6},{:.6},{:.6}\n",
                s.name,
                s.worker,
                s.batch,
                s.epoch,
                s.seq,
                s.t0,
                s.t1,
                s.duration()
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::names;
    use super::*;

    #[test]
    fn record_and_median() {
        let r = Recorder::new();
        r.record(names::GET_ITEM, 0, 1, 0.0, 0.1);
        r.record(names::GET_ITEM, 1, 1, 0.0, 0.3);
        r.record(names::GET_ITEM, 2, 2, 0.0, 0.2);
        assert_eq!(r.len(), 3);
        assert!((r.median(names::GET_ITEM) - 0.2).abs() < 1e-12);
    }

    #[test]
    fn time_closure() {
        let r = Recorder::new();
        let out = r.time(names::TRAIN_BATCH, 0, 0, || {
            std::thread::sleep(std::time::Duration::from_millis(10));
            5
        });
        assert_eq!(out, 5);
        let d = r.durations(names::TRAIN_BATCH);
        assert_eq!(d.len(), 1);
        assert!(d[0] >= 0.009);
    }

    #[test]
    fn disabled_recorder_drops_spans() {
        let r = Recorder::new();
        r.set_enabled(false);
        r.record("x", 0, 0, 0.0, 1.0);
        assert!(r.is_empty());
    }

    #[test]
    fn csv_has_rows() {
        let r = Recorder::new();
        r.record(names::GET_BATCH, 0, 0, 0.1, 0.4);
        let csv = r.to_csv();
        assert!(csv.starts_with("name,worker"));
        assert!(csv.contains("get_batch,0,0"));
    }

    #[test]
    fn summary_table_renders() {
        let r = Recorder::new();
        r.record(names::GET_BATCH, 0, 0, 0.0, 0.5);
        r.record(names::TO_DEVICE, 0, 0, 0.5, 0.6);
        let t = r.summary_table("spans");
        assert_eq!(t.rows.len(), 2);
    }

    #[test]
    fn tags_travel_with_the_span() {
        let r = Recorder::new();
        r.record_tagged(names::BATCH_INFLIGHT, 3, 17, 2, 41, 1.0, 1.5);
        let spans = r.snapshot();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].epoch, 2);
        assert_eq!(spans[0].seq, 41);
        // untagged records default to -1/-1
        r.record(names::GET_BATCH, 0, 0, 2.0, 2.1);
        let spans = r.snapshot();
        assert_eq!(spans[1].epoch, -1);
        assert_eq!(spans[1].seq, -1);
        assert!(r.to_csv().contains("batch_inflight,3,17,2,41"));
    }

    #[test]
    fn wraparound_keeps_the_newest_spans() {
        let r = Recorder::with_capacity(64); // 8 slots per shard
        for i in 0..1000 {
            r.record("w", 0, i, i as f64, i as f64 + 0.5);
        }
        let spans = r.snapshot();
        assert!(!spans.is_empty());
        assert!(spans.len() <= r.capacity());
        // single-threaded writers never tear, so nothing is dropped and
        // the retained window is the newest batch ids
        assert_eq!(r.dropped(), 0);
        assert!(spans.iter().all(|s| s.batch >= 1000 - r.capacity() as i64));
        assert!(spans.iter().any(|s| s.batch == 999));
    }

    #[test]
    fn clear_resets_the_rings() {
        let r = Recorder::with_capacity(64);
        for i in 0..100 {
            r.record("w", 0, i, 0.0, 1.0);
        }
        r.clear();
        assert!(r.is_empty());
        r.record("w", 0, 7, 0.0, 1.0);
        assert_eq!(r.len(), 1);
        assert_eq!(r.snapshot()[0].batch, 7);
    }
}
