//! CI-gated bench baselines: persist a `BENCH_*.json` snapshot of the
//! hotpath metrics in-repo and fail CI when a run regresses beyond a
//! tolerance band.
//!
//! File schema (pretty-printed, human-editable):
//!
//! ```json
//! {
//!   "schema": 1,
//!   "metrics": { "boundary.s3.pipelined_gap_ms": 120.0, ... },
//!   "higher_is_better": ["assembly.vanilla.speedup"],
//!   "tolerance": { "default": 0.75, "get_into.allocs_per_read": 0.0 },
//!   "slack": { "default": 2.0, "get_into.allocs_per_read": 0.0 }
//! }
//! ```
//!
//! A metric regresses when `current > base * (1 + tol) + slack` (or the
//! mirrored bound for `higher_is_better` metrics). Tolerances are wide
//! by design — the gate catches order-of-magnitude breakage (a lost
//! fast path, an alloc leak), not CI-runner jitter. `slack` is an
//! absolute floor in the metric's own unit so near-zero baselines don't
//! turn noise into failures.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};

pub const SCHEMA: u64 = 1;

/// Result of comparing a run against a baseline file.
#[derive(Debug, Default)]
pub struct BaselineOutcome {
    /// metrics compared against the baseline
    pub checked: usize,
    /// human-readable regression descriptions (empty = gate passes)
    pub regressions: Vec<String>,
    /// non-fatal observations (new metrics, large improvements)
    pub notes: Vec<String>,
}

impl BaselineOutcome {
    pub fn passed(&self) -> bool {
        self.regressions.is_empty()
    }
}

/// Write `metrics` as a fresh baseline file with the given default
/// tolerance band. Existing per-metric tolerance/slack edits are *not*
/// preserved — refresh deliberately, then re-tune the bands.
pub fn write(
    path: &str,
    metrics: &BTreeMap<String, f64>,
    higher_is_better: &[&str],
    default_tolerance: f64,
    default_slack: f64,
) -> Result<()> {
    let mut m = Json::obj();
    for (k, v) in metrics {
        m.set(k, *v);
    }
    let mut tol = Json::obj();
    tol.set("default", default_tolerance);
    let mut slack = Json::obj();
    slack.set("default", default_slack);
    let mut doc = Json::obj();
    doc.set("schema", SCHEMA)
        .set("metrics", m)
        .set("higher_is_better", higher_is_better.to_vec())
        .set("tolerance", tol)
        .set("slack", slack);
    std::fs::write(path, doc.pretty() + "\n")
        .with_context(|| format!("write baseline {path}"))?;
    Ok(())
}

fn band(doc: &Json, table: &str, name: &str, fallback: f64) -> f64 {
    doc.at(&[table, name])
        .or_else(|| doc.at(&[table, "default"]))
        .and_then(|j| j.as_f64())
        .unwrap_or(fallback)
}

/// Compare `current` against the baseline at `path`.
pub fn check(path: &str, current: &BTreeMap<String, f64>) -> Result<BaselineOutcome> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read baseline {path}"))?;
    let doc = json::parse(&text).with_context(|| format!("parse baseline {path}"))?;
    let schema = doc.get("schema").and_then(|j| j.as_f64()).unwrap_or(0.0) as u64;
    if schema != SCHEMA {
        bail!("baseline {path} has schema {schema}, expected {SCHEMA}");
    }
    let Some(base) = doc.get("metrics").and_then(|j| j.as_obj()) else {
        bail!("baseline {path} has no metrics object");
    };
    let hib: Vec<&str> = doc
        .get("higher_is_better")
        .and_then(|j| j.as_arr())
        .map(|a| a.iter().filter_map(|j| j.as_str()).collect())
        .unwrap_or_default();
    let mut out = BaselineOutcome::default();
    for (name, bval) in base {
        let Some(b) = bval.as_f64() else { continue };
        let Some(&cur) = current.get(name) else {
            out.regressions
                .push(format!("{name}: present in baseline but missing from this run"));
            continue;
        };
        out.checked += 1;
        let tol = band(&doc, "tolerance", name, 0.5);
        let slack = band(&doc, "slack", name, 0.0);
        if hib.contains(&name.as_str()) {
            let floor = b * (1.0 - tol) - slack;
            if cur < floor {
                out.regressions.push(format!(
                    "{name}: {cur:.3} below baseline {b:.3} (floor {floor:.3}, tol {tol:.2}, slack {slack:.2})"
                ));
            } else if cur > b * (1.0 + tol) + slack {
                out.notes.push(format!(
                    "{name}: {cur:.3} well above baseline {b:.3} — consider refreshing"
                ));
            }
        } else {
            let ceil = b * (1.0 + tol) + slack;
            if cur > ceil {
                out.regressions.push(format!(
                    "{name}: {cur:.3} above baseline {b:.3} (ceiling {ceil:.3}, tol {tol:.2}, slack {slack:.2})"
                ));
            } else if b > 0.0 && cur < b * (1.0 - tol) - slack {
                out.notes.push(format!(
                    "{name}: {cur:.3} well below baseline {b:.3} — consider refreshing"
                ));
            }
        }
    }
    for name in current.keys() {
        if !base.contains_key(name) {
            out.notes.push(format!("{name}: new metric, not gated yet"));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> String {
        let dir = std::env::temp_dir().join(format!("cdl-baseline-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name).to_string_lossy().into_owned()
    }

    fn metrics(pairs: &[(&str, f64)]) -> BTreeMap<String, f64> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    #[test]
    fn roundtrip_within_band_passes() {
        let path = tmp("ok.json");
        let base = metrics(&[("a.ms", 100.0), ("b.count", 0.0)]);
        write(&path, &base, &[], 0.5, 1.0).unwrap();
        let cur = metrics(&[("a.ms", 130.0), ("b.count", 0.0)]);
        let out = check(&path, &cur).unwrap();
        assert!(out.passed(), "{:?}", out.regressions);
        assert_eq!(out.checked, 2);
    }

    #[test]
    fn regression_beyond_band_fails() {
        let path = tmp("regress.json");
        write(&path, &metrics(&[("a.ms", 100.0)]), &[], 0.5, 1.0).unwrap();
        let out = check(&path, &metrics(&[("a.ms", 200.0)])).unwrap();
        assert!(!out.passed());
        assert!(out.regressions[0].contains("a.ms"));
    }

    #[test]
    fn zero_baseline_gates_hard_without_slack() {
        let path = tmp("zero.json");
        write(&path, &metrics(&[("allocs", 0.0)]), &[], 0.5, 0.0).unwrap();
        assert!(check(&path, &metrics(&[("allocs", 1.0)])).unwrap().regressions.len() == 1);
        assert!(check(&path, &metrics(&[("allocs", 0.0)])).unwrap().passed());
    }

    #[test]
    fn higher_is_better_mirrors_the_band() {
        let path = tmp("hib.json");
        write(&path, &metrics(&[("speedup", 2.0)]), &["speedup"], 0.5, 0.0).unwrap();
        assert!(check(&path, &metrics(&[("speedup", 1.5)])).unwrap().passed());
        assert!(!check(&path, &metrics(&[("speedup", 0.5)])).unwrap().passed());
    }

    #[test]
    fn missing_and_new_metrics_are_reported() {
        let path = tmp("drift.json");
        write(&path, &metrics(&[("gone.ms", 5.0)]), &[], 0.5, 0.0).unwrap();
        let out = check(&path, &metrics(&[("fresh.ms", 5.0)])).unwrap();
        assert!(!out.passed());
        assert!(out.regressions[0].contains("gone.ms"));
        assert!(out.notes.iter().any(|n| n.contains("fresh.ms")));
    }
}
