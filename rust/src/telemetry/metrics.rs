//! `MetricsHub` — one registry of named atomic counters/gauges.
//!
//! The pipeline used to scatter its health signals across ad-hoc
//! accessors (`reorder_high_water`, `item_steals`, planner seam idle,
//! CreditGate block time, cache tier stats, prefetch hit counters,
//! allocator counters). The hub absorbs them into a single namespace so
//! one `snapshot()` renders the whole plane as structured JSON
//! (`cdl run --metrics out.jsonl` streams one snapshot per epoch).
//!
//! Registration (`metric()`) takes a Mutex and may allocate — do it at
//! setup and cache the returned `Arc<Metric>`; updating a metric is a
//! single relaxed atomic op and is safe inside the zero-alloc window.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::util::json::Json;

/// One named counter/gauge: a plain atomic u64 (counts or nanoseconds).
#[derive(Debug, Default)]
pub struct Metric {
    bits: AtomicU64,
}

impl Metric {
    pub fn add(&self, v: u64) {
        self.bits.fetch_add(v, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.add(1);
    }

    /// Accumulate a duration in nanoseconds.
    pub fn add_duration(&self, d: Duration) {
        self.add(d.as_nanos() as u64);
    }

    pub fn set(&self, v: u64) {
        self.bits.store(v, Ordering::Relaxed);
    }

    /// Raise-only gauge (high-water marks).
    pub fn set_max(&self, v: u64) {
        self.bits.fetch_max(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.bits.load(Ordering::Relaxed)
    }
}

/// Registry of named metrics. Cheap to update, locked only to register
/// or snapshot.
#[derive(Debug, Default)]
pub struct MetricsHub {
    registry: Mutex<BTreeMap<String, Arc<Metric>>>,
}

impl MetricsHub {
    pub fn new() -> MetricsHub {
        MetricsHub::default()
    }

    /// Get-or-register the metric named `name`. Cache the handle for
    /// hot-path use.
    pub fn metric(&self, name: &str) -> Arc<Metric> {
        let mut reg = self.registry.lock().unwrap();
        if let Some(m) = reg.get(name) {
            return m.clone();
        }
        let m = Arc::new(Metric::default());
        reg.insert(name.to_string(), m.clone());
        m
    }

    /// Convenience: set a gauge by name (registers it if new). Not for
    /// hot paths — takes the registry lock.
    pub fn set(&self, name: &str, v: u64) {
        self.metric(name).set(v);
    }

    /// Convenience: bump a counter by name (registers it if new).
    pub fn add(&self, name: &str, v: u64) {
        self.metric(name).add(v);
    }

    /// Current value of `name`, 0 if never registered.
    pub fn get(&self, name: &str) -> u64 {
        self.registry
            .lock()
            .unwrap()
            .get(name)
            .map(|m| m.get())
            .unwrap_or(0)
    }

    /// All registered metric names (sorted).
    pub fn names(&self) -> Vec<String> {
        self.registry.lock().unwrap().keys().cloned().collect()
    }

    /// Structured snapshot of every registered metric: a JSON object
    /// with sorted keys (deterministic for golden files).
    pub fn snapshot(&self) -> Json {
        let reg = self.registry.lock().unwrap();
        let mut obj = Json::obj();
        for (name, m) in reg.iter() {
            obj.set(name, m.get());
        }
        obj
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_update_snapshot() {
        let hub = MetricsHub::new();
        let steals = hub.metric("loader.item_steals");
        steals.add(3);
        steals.inc();
        hub.set("reorder.high_water", 6);
        hub.metric("planner.seam_idle_ns").add_duration(Duration::from_micros(1500));
        assert_eq!(hub.get("loader.item_steals"), 4);
        assert_eq!(hub.get("reorder.high_water"), 6);
        assert_eq!(hub.get("planner.seam_idle_ns"), 1_500_000);
        assert_eq!(hub.get("never.registered"), 0);
        let snap = hub.snapshot();
        assert_eq!(snap.at(&["loader.item_steals"]).and_then(|j| j.as_usize()), Some(4));
    }

    #[test]
    fn metric_handles_are_shared() {
        let hub = MetricsHub::new();
        let a = hub.metric("x");
        let b = hub.metric("x");
        a.add(2);
        b.add(5);
        assert_eq!(hub.get("x"), 7);
        assert_eq!(hub.names(), vec!["x".to_string()]);
    }

    #[test]
    fn set_max_is_a_high_water_mark() {
        let hub = MetricsHub::new();
        let m = hub.metric("hwm");
        m.set_max(4);
        m.set_max(2);
        m.set_max(9);
        assert_eq!(m.get(), 9);
    }
}
