//! Offline stub of the `xla` crate (xla-rs PJRT bindings).
//!
//! The build environment has no network access and no PJRT shared
//! libraries, so the real bindings cannot be built. This stub provides
//! the exact type/method surface `cdl::runtime` compiles against;
//! [`PjRtClient::cpu`] fails with a descriptive error, which the engine
//! thread already handles by failing every request (the runtime tests
//! skip themselves when no artifacts are built, so nothing reaches the
//! data path in a stubbed build). Swapping in the real `xla` crate is a
//! Cargo.toml change only.

use std::fmt;
use std::path::Path;

/// Stub error: every fallible operation returns this.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn new(what: &str) -> Error {
        Error(format!(
            "{what}: XLA/PJRT unavailable in this offline build \
             (stub crate rust/vendor/xla)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// XLA element types (subset used by the artifacts, plus a marker so
/// `match` arms over unexpected types stay reachable).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[non_exhaustive]
pub enum ElementType {
    Pred,
    U8,
    S32,
    F32,
    F64,
}

/// Parsed HLO module (stub: retains nothing).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(Error::new(&format!(
            "parse HLO text {:?}",
            path.as_ref()
        )))
    }
}

/// An XLA computation (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Array shape of a literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// A host literal (stub: carries no data).
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Err(Error::new("create literal"))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(Error::new("literal shape"))
    }

    pub fn element_count(&self) -> usize {
        0
    }

    pub fn copy_raw_to<T>(&self, _dst: &mut [T]) -> Result<()> {
        Err(Error::new("copy literal"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::new("untuple literal"))
    }

    pub fn get_first_element<T>(&self) -> Result<T> {
        Err(Error::new("read literal element"))
    }
}

/// Device-side buffer handle (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::new("buffer to literal"))
    }
}

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new("execute"))
    }
}

/// PJRT client (stub: construction always fails, loudly).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::new("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new("compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_loudly_not_silently() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        let msg = format!("{err}");
        assert!(msg.contains("offline"), "{msg}");
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        assert_eq!(Literal.element_count(), 0);
    }
}
