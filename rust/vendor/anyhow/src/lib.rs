//! Minimal, std-only stand-in for the `anyhow` crate.
//!
//! The build environment is fully offline, so the real `anyhow` cannot be
//! fetched from crates.io. This shim implements the (small) subset of its
//! API that `cdl` uses — [`Error`], [`Result`], the [`anyhow!`], [`bail!`]
//! and [`ensure!`] macros, and the [`Context`] extension trait — with the
//! same semantics:
//!
//! * any `std::error::Error + Send + Sync + 'static` converts into
//!   [`Error`] via `?` (the source chain is captured);
//! * `Display` shows the outermost message, `{:#}` shows the full
//!   `outer: inner: root` chain, and `Debug` shows an anyhow-style
//!   "Caused by:" listing;
//! * [`Error`] deliberately does **not** implement `std::error::Error`,
//!   which is what makes the blanket `From` impl coherent (the same trick
//!   the real crate uses).

use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-carrying error: an outermost message plus the chain of
/// underlying causes, innermost last.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (what `.context(...)` does).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root) cause message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// Coherent because Error itself does not implement std::error::Error.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Attach context to errors, mirroring `anyhow::Context`.
pub trait Context<T, E> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Wrap the error with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::{Context, Error, Result};

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<u32> {
            let n: u32 = "42".parse()?;
            Ok(n)
        }
        assert_eq!(inner().unwrap(), 42);

        fn bad() -> Result<u32> {
            let n: u32 = "nope".parse()?;
            Ok(n)
        }
        assert!(bad().is_err());
    }

    #[test]
    fn context_chains_and_formats() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading config")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
    }

    #[test]
    fn with_context_is_lazy() {
        let mut called = false;
        let ok: Result<u32> = Ok::<_, Error>(7).with_context(|| {
            called = true;
            "ctx"
        });
        assert_eq!(ok.unwrap(), 7);
        assert!(!called);
    }

    #[test]
    fn option_context() {
        let v: Result<u32> = None.context("empty");
        assert_eq!(format!("{}", v.unwrap_err()), "empty");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            crate::ensure!(x < 10, "too big: {x}");
            if x == 3 {
                crate::bail!("unlucky {x}");
            }
            Ok(x)
        }
        assert_eq!(f(1).unwrap(), 1);
        assert_eq!(format!("{}", f(3).unwrap_err()), "unlucky 3");
        assert_eq!(format!("{}", f(11).unwrap_err()), "too big: 11");
        let e = crate::anyhow!("x = {}", 5);
        assert_eq!(format!("{e}"), "x = 5");
    }
}
