//! Integration tests for the PJRT runtime against the real AOT
//! artifacts: loss numbers must match the python-side smoke values from
//! `manifest.json`, and the kernel-only artifacts must match rust-side
//! reference math.
//!
//! Skipped (with a loud message) when `artifacts/` hasn't been built —
//! run `make artifacts` first.

use std::sync::Arc;

use cdl::runtime::{example_batch, Dtype, HostTensor, XlaEngine};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("SKIP: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn engine_loads_and_lists_artifacts() {
    let dir = require_artifacts!();
    let engine = XlaEngine::start(dir).unwrap();
    let names = engine.manifest().artifact_names();
    assert!(names.iter().any(|n| n == "init"), "{names:?}");
    assert!(names.iter().any(|n| n.starts_with("train_step")));
    assert!(engine.manifest().num_params() > 100_000);
}

#[test]
fn init_params_match_manifest_shapes() {
    let dir = require_artifacts!();
    let engine = XlaEngine::start(dir).unwrap();
    engine.init_params().unwrap();
    let params = engine.get_params().unwrap();
    let specs = engine.manifest().param_specs().unwrap();
    assert_eq!(params.len(), specs.len());
    for (p, s) in params.iter().zip(&specs) {
        assert_eq!(p.dims, s.shape, "{}", s.name);
        assert_eq!(p.dtype, Dtype::F32, "{}", s.name);
    }
    let total: usize = params.iter().map(|p| p.bytes.len() / 4).sum();
    assert_eq!(total, engine.manifest().num_params());
}

#[test]
fn train_step_reproduces_python_smoke_losses() {
    let dir = require_artifacts!();
    let engine = XlaEngine::start(dir).unwrap();
    let smoke = engine.manifest().smoke().expect("manifest has smoke block");
    engine.init_params().unwrap();
    let classes = engine.manifest().num_classes();
    let (images, labels) = example_batch(smoke.batch, smoke.image, classes);
    for (step, want) in smoke.losses.iter().enumerate() {
        let got = engine
            .train_step(&smoke.variant, images.clone(), labels.clone())
            .unwrap() as f64;
        let rel = ((got - want) / want).abs();
        assert!(
            rel < smoke.rtol.max(1e-3),
            "step {step}: rust loss {got} vs python {want} (rel {rel:.2e})"
        );
    }
}

#[test]
fn training_reduces_loss_over_steps() {
    let dir = require_artifacts!();
    let engine = XlaEngine::start(dir).unwrap();
    engine.init_params().unwrap();
    let smoke = engine.manifest().smoke().unwrap();
    let classes = engine.manifest().num_classes();
    let (images, labels) = example_batch(smoke.batch, smoke.image, classes);
    let mut losses = Vec::new();
    for _ in 0..5 {
        losses.push(
            engine
                .train_step(&smoke.variant, images.clone(), labels.clone())
                .unwrap(),
        );
    }
    assert!(
        losses[4] < losses[0],
        "loss did not decrease on a fixed batch: {losses:?}"
    );
}

#[test]
fn normalize_kernel_artifact_matches_reference() {
    let dir = require_artifacts!();
    let engine = XlaEngine::start(dir).unwrap();
    // artifact shape: (4, 32, 32, 3) u8
    let n = 4 * 32 * 32 * 3;
    let data: Vec<u8> = (0..n).map(|i| (i * 7 % 256) as u8).collect();
    let input = HostTensor::from_u8(&[4, 32, 32, 3], data.clone());
    let out = engine.run("normalize_b4_i32", vec![input]).unwrap();
    assert_eq!(out.len(), 1);
    let got = out[0].to_f32_vec();
    // rust-side reference: (x/255 - mean)/std per channel
    const MEAN: [f32; 3] = [0.485, 0.456, 0.406];
    const STD: [f32; 3] = [0.229, 0.224, 0.225];
    for (i, (&raw, &g)) in data.iter().zip(&got).enumerate() {
        let c = i % 3;
        let want = (raw as f32 / 255.0 - MEAN[c]) / STD[c];
        assert!(
            (g - want).abs() < 1e-5,
            "elem {i}: got {g}, want {want}"
        );
    }
}

#[test]
fn matmul_kernel_artifact_matches_reference() {
    let dir = require_artifacts!();
    let engine = XlaEngine::start(dir).unwrap();
    let n = 128usize;
    let mut rng = cdl::util::rng::Rng::new(42);
    let a: Vec<f32> = (0..n * n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
    let b: Vec<f32> = (0..n * n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
    let out = engine
        .run(
            "matmul_128",
            vec![
                HostTensor::from_f32(&[n, n], &a),
                HostTensor::from_f32(&[n, n], &b),
            ],
        )
        .unwrap();
    let got = out[0].to_f32_vec();
    // spot-check a handful of entries against naive matmul
    for &(i, j) in &[(0usize, 0usize), (1, 7), (64, 64), (127, 127), (13, 100)] {
        let mut want = 0f32;
        for k in 0..n {
            want += a[i * n + k] * b[k * n + j];
        }
        let g = got[i * n + j];
        assert!(
            (g - want).abs() < 1e-3 * (1.0 + want.abs()),
            "({i},{j}): got {g}, want {want}"
        );
    }
}

#[test]
fn forward_produces_finite_logits() {
    let dir = require_artifacts!();
    let engine = XlaEngine::start(dir).unwrap();
    engine.init_params().unwrap();
    let classes = engine.manifest().num_classes();
    let (images, _) = example_batch(16, 64, classes);
    let logits = engine.forward("forward_b16_i64", images).unwrap();
    assert_eq!(logits.dims, vec![16, classes]);
    assert!(logits.to_f32_vec().iter().all(|v| v.is_finite()));
}

#[test]
fn xla_device_trains_through_the_full_stack() {
    // the e2e composition test: loader -> device(XLA) -> loss
    let dir = require_artifacts!();
    use cdl::data::synth::{generate_corpus, CorpusSpec};
    use cdl::data::AugmentConfig;
    use cdl::dataloader::{Dataloader, DataloaderConfig, FetchImpl};
    use cdl::dataset::{Dataset, ImageFolderDataset};
    use cdl::device::Device;
    use cdl::storage::{MemStore, ObjectStore};
    use cdl::telemetry::Recorder;

    let engine = Arc::new(XlaEngine::start(dir).unwrap());
    engine.init_params().unwrap();
    let variant = engine.manifest().train_variant(8, 32).unwrap();

    let mem: Arc<dyn ObjectStore> = Arc::new(MemStore::new("m"));
    generate_corpus(&mem, &CorpusSpec::tiny(32)).unwrap();
    let ds: Arc<dyn Dataset> = Arc::new(ImageFolderDataset::new(
        mem,
        AugmentConfig { crop: 32, ..Default::default() },
    ));
    let rec = Recorder::new();
    let dl = Dataloader::new(
        ds,
        DataloaderConfig {
            batch_size: 8,
            num_workers: 2,
            fetch_impl: FetchImpl::Threaded,
            drop_last: true,
            spawn_cost_override: Some(std::time::Duration::ZERO),
            ..Default::default()
        },
        rec.clone(),
    );
    let device = Device::xla(engine, &variant, rec);
    let mut losses = Vec::new();
    for b in dl.epoch(0) {
        let db = device.to_device(b);
        losses.push(device.train_batch(&db).unwrap());
    }
    assert_eq!(losses.len(), 4);
    assert!(losses.iter().all(|l| l.is_finite()));
}
