//! Acceptance assertions for the `hotpath` experiment: fused arena
//! assembly beats the legacy copy path and collapses the per-batch
//! allocation count; work-stealing dispatch never regresses the
//! straggler tail.
//!
//! This file deliberately contains a single test: the measurements read
//! wall clocks and the process-wide allocation counters of the counting
//! global allocator, so they need a quiet process (test binaries run
//! sequentially; tests *within* a binary do not).
//!
//! Wall-clock thresholds are deliberately two-tier: the unconditional
//! bounds only catch catastrophic regressions (they must hold even on a
//! noisy shared CI runner); `CDL_STRICT_PERF=1` enforces the PR's
//! acceptance criteria (arena ≥ 1.5× batches/s, stealing p99 strictly
//! below static on s3) for quiet benchmarking machines. The
//! *allocation* assertions are deterministic and always strict.

use cdl::bench::exp_hotpath::{assembly_table, stealing_table};
use cdl::bench::Scale;

#[test]
fn hotpath_experiment_acceptance() {
    let strict = std::env::var("CDL_STRICT_PERF").as_deref() == Ok("1");
    let scale = Scale { latency: 0.05, items: 1.0, epochs: 1.0 };

    // ---- fused assembly: throughput up, allocations collapsed -------
    let (t, vanilla_speedup) = assembly_table(scale).unwrap();
    assert_eq!(t.rows.len(), 6);
    let speedup_floor = if strict { 1.5 } else { 0.85 };
    assert!(
        vanilla_speedup >= speedup_floor,
        "fused assembly speedup only {vanilla_speedup:.2}x (floor {speedup_floor})"
    );
    // allocs/batch: arena-on strictly below arena-off for every fetcher
    // (rows alternate off/on per impl) — deterministic, always strict.
    // Only meaningful when the counting allocator is installed (the
    // default count-alloc feature); without it every cell reads 0.
    if cdl::util::alloc::counters().allocs > 0 {
        for pair in t.rows.chunks(2) {
            let off: f64 = pair[0][5].parse().unwrap();
            let on: f64 = pair[1][5].parse().unwrap();
            assert!(
                on < off,
                "{} arena-on allocs/batch {on} !< arena-off {off}",
                pair[0][0]
            );
        }
        // vanilla fused must eliminate the per-item decode+crop
        // allocations wholesale, not just shave them
        let off: f64 = t.rows[0][5].parse().unwrap();
        let on: f64 = t.rows[1][5].parse().unwrap();
        assert!(on < off / 2.0, "vanilla: {on} allocs/batch not ≪ {off}");
    }

    // ---- work stealing: tail no worse than static dispatch ----------
    let (t, static_p99, steal_p99) = stealing_table(scale).unwrap();
    assert_eq!(t.rows.len(), 6);
    assert!(static_p99 > 0.0 && steal_p99 > 0.0);
    let tail_ceiling = if strict { 1.0 } else { 1.75 };
    assert!(
        steal_p99 <= static_p99 * tail_ceiling,
        "stealing p99 {steal_p99:.4}s regressed vs static {static_p99:.4}s \
         (ceiling {tail_ceiling}x)"
    );
}
