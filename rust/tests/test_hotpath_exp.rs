//! Acceptance assertions for the `hotpath` experiment: fused arena
//! assembly beats the legacy copy path and collapses the per-batch
//! allocation count; item-steal dispatch never regresses the straggler
//! tail vs batch-steal; the credit-bounded reorder buffer and the
//! zero-alloc `get_into` read path hold their invariants; pinned slabs
//! beat pageable transfers.
//!
//! This file deliberately contains a single test: the measurements read
//! wall clocks and the process-wide allocation counters of the counting
//! global allocator, so they need a quiet process (test binaries run
//! sequentially; tests *within* a binary do not).
//!
//! Wall-clock thresholds are deliberately two-tier: the unconditional
//! bounds only catch catastrophic regressions (they must hold even on a
//! noisy shared CI runner); `CDL_STRICT_PERF=1` enforces the PR's
//! acceptance criteria (arena ≥ 1.5× batches/s, item-steal p99 ≤
//! batch-steal p99 on ceph_os) for quiet benchmarking machines. The
//! *allocation* and *reorder-buffer* assertions are deterministic and
//! always strict (the tail/get_into tables bail internally on a
//! high-water or allocation regression).

use cdl::bench::exp_hotpath::{
    assembly_table, boundary_table, get_into_table, pinned_table, tail_table,
};
use cdl::bench::Scale;

#[test]
fn hotpath_experiment_acceptance() {
    let strict = std::env::var("CDL_STRICT_PERF").as_deref() == Ok("1");
    let scale = Scale { latency: 0.05, items: 1.0, epochs: 1.0 };

    // ---- fused assembly: throughput up, allocations collapsed -------
    let (t, vanilla_speedup) = assembly_table(scale).unwrap();
    assert_eq!(t.rows.len(), 6);
    let speedup_floor = if strict { 1.5 } else { 0.85 };
    assert!(
        vanilla_speedup >= speedup_floor,
        "fused assembly speedup only {vanilla_speedup:.2}x (floor {speedup_floor})"
    );
    // allocs/batch: arena-on strictly below arena-off for every fetcher
    // (rows alternate off/on per impl) — deterministic, always strict.
    // Only meaningful when the counting allocator is installed (the
    // default count-alloc feature); without it every cell reads 0.
    if cdl::util::alloc::counters().allocs > 0 {
        for pair in t.rows.chunks(2) {
            let off: f64 = pair[0][5].parse().unwrap();
            let on: f64 = pair[1][5].parse().unwrap();
            assert!(
                on < off,
                "{} arena-on allocs/batch {on} !< arena-off {off}",
                pair[0][0]
            );
        }
        // vanilla fused must eliminate the per-item decode+crop
        // allocations wholesale, not just shave them
        let off: f64 = t.rows[0][5].parse().unwrap();
        let on: f64 = t.rows[1][5].parse().unwrap();
        assert!(on < off / 2.0, "vanilla: {on} allocs/batch not ≪ {off}");
    }

    // ---- dispatch tail: item-steal no worse than batch-steal --------
    // tail_table itself fails the run if any cell's reorder-buffer
    // high-water mark exceeds TAIL_CREDIT, so the credit bound is
    // enforced unconditionally just by running it.
    let (t, batch_p99, item_p99) = tail_table(scale).unwrap();
    assert_eq!(t.rows.len(), 9);
    assert!(batch_p99 > 0.0 && item_p99 > 0.0);
    let tail_ceiling = if strict { 1.0 } else { 1.75 };
    assert!(
        item_p99 <= batch_p99 * tail_ceiling,
        "item-steal p99 {item_p99:.4}s regressed vs batch-steal \
         {batch_p99:.4}s on ceph_os (ceiling {tail_ceiling}x)"
    );

    // ---- epoch boundary: pipelined gap < drained gap on s3 ----------
    // boundary_table itself bails if the pipelined inter-epoch gap is
    // not strictly smaller than the drained one on the s3 profile, and
    // if any cell's through-the-seam reorder high-water exceeds the
    // credit, so both bars are enforced just by running it.
    let (t, drained_gap, pipelined_gap) = boundary_table(scale).unwrap();
    assert_eq!(t.rows.len(), 6);
    assert!(drained_gap > 0.0 && pipelined_gap > 0.0);
    assert!(pipelined_gap < drained_gap);

    // ---- pinned slabs: transfers strictly faster than pageable ------
    // the transfer model is sleep-based (400µs + b/6GBps pageable vs
    // 100µs + b/12GBps pinned), so a comfortable margin is deterministic
    let (t, pageable_ms, pinned_ms) = pinned_table(scale).unwrap();
    assert_eq!(t.rows.len(), 2);
    assert!(
        pinned_ms < pageable_ms,
        "pinned transfer {pinned_ms:.3} ms !< pageable {pageable_ms:.3} ms"
    );

    // ---- get_into: zero-alloc steady-state DirStore reads -----------
    // get_into_table bails internally on a nonzero allocs/read when the
    // counting allocator is installed
    let (t, into_allocs) = get_into_table(scale).unwrap();
    assert_eq!(t.rows.len(), 2);
    if cdl::util::alloc::counters().allocs > 0 {
        assert_eq!(into_allocs, 0.0, "get_into allocated in steady state");
    }
}
