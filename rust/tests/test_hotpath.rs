//! Byte-identical equivalence of the fused arena assembly against the
//! legacy copy path: same images, labels, indices, and raw-byte counts
//! for every fetcher implementation, both dispatch modes, partial
//! batches, and recycled slabs across epochs.

use std::sync::Arc;
use std::time::Duration;

use cdl::data::synth::{generate_corpus, CorpusSpec};
use cdl::data::AugmentConfig;
use cdl::dataloader::{Batch, Dataloader, DataloaderConfig, FetchImpl};
use cdl::dataset::{Dataset, ImageFolderDataset};
use cdl::storage::{MemStore, ObjectStore};
use cdl::telemetry::Recorder;

const ITEMS: usize = 37; // not a multiple of the batch size: partial tail
const BATCH: usize = 8;

fn dataset() -> Arc<dyn Dataset> {
    let mem: Arc<dyn ObjectStore> = Arc::new(MemStore::new("m"));
    generate_corpus(&mem, &CorpusSpec::tiny(ITEMS)).unwrap();
    Arc::new(ImageFolderDataset::new(
        mem,
        AugmentConfig { crop: 16, ..Default::default() },
    ))
}

fn loader(fetch: FetchImpl, arena_slabs: usize, work_stealing: bool) -> Dataloader {
    Dataloader::new(
        dataset(),
        DataloaderConfig {
            batch_size: BATCH,
            num_workers: 3,
            fetch_impl: fetch,
            num_fetch_workers: 4,
            arena_slabs,
            work_stealing,
            spawn_cost_override: Some(Duration::ZERO),
            ..Default::default()
        },
        Recorder::new(),
    )
}

fn assert_batches_identical(legacy: &[Batch], fused: &[Batch], ctx: &str) {
    assert_eq!(legacy.len(), fused.len(), "{ctx}: batch count");
    for (a, b) in legacy.iter().zip(fused.iter()) {
        assert_eq!(a.id, b.id, "{ctx}");
        assert_eq!(a.images.shape, b.images.shape, "{ctx}: batch {}", a.id);
        assert_eq!(a.images.data, b.images.data, "{ctx}: batch {} bytes", a.id);
        assert_eq!(a.labels, b.labels, "{ctx}: batch {}", a.id);
        assert_eq!(a.indices, b.indices, "{ctx}: batch {}", a.id);
        assert_eq!(a.raw_bytes, b.raw_bytes, "{ctx}: batch {}", a.id);
    }
}

#[test]
fn fused_assembly_is_byte_identical_for_every_fetcher() {
    for fetch in FetchImpl::all() {
        let legacy: Vec<Batch> = loader(fetch, 0, false).epoch(0).collect();
        assert!(legacy.last().unwrap().len() == ITEMS % BATCH); // partial tail
        let fused: Vec<Batch> = loader(fetch, 12, false).epoch(0).collect();
        assert!(fused.iter().all(|b| b.is_pooled()));
        assert_batches_identical(&legacy, &fused, fetch.label());
    }
}

#[test]
fn fused_assembly_is_byte_identical_under_work_stealing() {
    for fetch in FetchImpl::all() {
        let legacy: Vec<Batch> = loader(fetch, 0, false).epoch(0).collect();
        let fused: Vec<Batch> = loader(fetch, 12, true).epoch(0).collect();
        assert_batches_identical(&legacy, &fused, fetch.label());
    }
}

#[test]
fn recycled_slabs_stay_byte_identical_across_epochs() {
    // one fused loader reusing its slab pool for three epochs must keep
    // matching a fresh legacy loader epoch by epoch — any stale-slot or
    // truncation bug in the recycle path shows up here
    let fused_dl = loader(FetchImpl::Threaded, 10, true);
    for epoch in 0..3 {
        let legacy: Vec<Batch> =
            loader(FetchImpl::Threaded, 0, false).epoch(epoch).collect();
        let fused: Vec<Batch> = fused_dl.epoch(epoch).collect();
        assert_batches_identical(&legacy, &fused, &format!("epoch {epoch}"));
        for b in fused {
            b.recycle();
        }
    }
    let stats = fused_dl.arena().unwrap().stats();
    assert!(stats.reused > 0, "{stats:?}");
    assert_eq!(stats.checkouts, 15, "{stats:?}"); // 5 batches × 3 epochs
}

#[test]
fn inline_loader_fused_matches_legacy() {
    let mk = |arena_slabs| {
        Dataloader::new(
            dataset(),
            DataloaderConfig {
                batch_size: BATCH,
                num_workers: 0, // inline in the consumer
                arena_slabs,
                ..Default::default()
            },
            Recorder::new(),
        )
    };
    let legacy: Vec<Batch> = mk(0).epoch(0).collect();
    let fused: Vec<Batch> = mk(4).epoch(0).collect();
    assert!(fused.iter().all(|b| b.is_pooled()));
    assert_batches_identical(&legacy, &fused, "inline");
}

#[test]
fn fused_batch_pool_disassembly_matches_legacy() {
    let mk = |arena_slabs| {
        Dataloader::new(
            dataset(),
            DataloaderConfig {
                batch_size: BATCH,
                num_workers: 2,
                fetch_impl: FetchImpl::Threaded,
                num_fetch_workers: 8,
                batch_pool: 2 * BATCH, // two batches per wave
                arena_slabs,
                spawn_cost_override: Some(Duration::ZERO),
                ..Default::default()
            },
            Recorder::new(),
        )
    };
    let legacy: Vec<Batch> = mk(0).epoch(0).collect();
    let fused: Vec<Batch> = mk(12).epoch(0).collect();
    assert_batches_identical(&legacy, &fused, "batch_pool");
}
