//! Byte-identical equivalence of the fused arena assembly against the
//! legacy copy path: same images, labels, indices, and raw-byte counts
//! for every fetcher implementation, every dispatch mode (static,
//! batch-steal, item-steal), the `get_into` scratch-read path, partial
//! batches, and recycled slabs across epochs. Plus the consumer-credit
//! stress: under an adversarial straggler schedule the reorder buffer
//! never exceeds `consumer_credit`.

use std::sync::Arc;
use std::time::Duration;

use cdl::data::synth::{generate_corpus, CorpusSpec};
use cdl::data::AugmentConfig;
use cdl::dataloader::{Batch, Dataloader, DataloaderConfig, FetchImpl};
use cdl::dataset::{Dataset, ImageFolderDataset};
use cdl::storage::{Bytes, MemStore, ObjectStore, StoreStats};
use cdl::telemetry::Recorder;

const ITEMS: usize = 37; // not a multiple of the batch size: partial tail
const BATCH: usize = 8;

fn dataset() -> Arc<dyn Dataset> {
    let mem: Arc<dyn ObjectStore> = Arc::new(MemStore::new("m"));
    generate_corpus(&mem, &CorpusSpec::tiny(ITEMS)).unwrap();
    Arc::new(ImageFolderDataset::new(
        mem,
        AugmentConfig { crop: 16, ..Default::default() },
    ))
}

fn loader(fetch: FetchImpl, arena_slabs: usize, work_stealing: bool) -> Dataloader {
    Dataloader::new(
        dataset(),
        DataloaderConfig {
            batch_size: BATCH,
            num_workers: 3,
            fetch_impl: fetch,
            num_fetch_workers: 4,
            arena_slabs,
            work_stealing,
            spawn_cost_override: Some(Duration::ZERO),
            ..Default::default()
        },
        Recorder::new(),
    )
}

fn assert_batches_identical(legacy: &[Batch], fused: &[Batch], ctx: &str) {
    assert_eq!(legacy.len(), fused.len(), "{ctx}: batch count");
    for (a, b) in legacy.iter().zip(fused.iter()) {
        assert_eq!(a.id, b.id, "{ctx}");
        assert_eq!(a.images.shape, b.images.shape, "{ctx}: batch {}", a.id);
        assert_eq!(a.images.data, b.images.data, "{ctx}: batch {} bytes", a.id);
        assert_eq!(a.labels, b.labels, "{ctx}: batch {}", a.id);
        assert_eq!(a.indices, b.indices, "{ctx}: batch {}", a.id);
        assert_eq!(a.raw_bytes, b.raw_bytes, "{ctx}: batch {}", a.id);
    }
}

#[test]
fn fused_assembly_is_byte_identical_for_every_fetcher() {
    for fetch in FetchImpl::all() {
        let legacy: Vec<Batch> = loader(fetch, 0, false).epoch(0).collect();
        assert!(legacy.last().unwrap().len() == ITEMS % BATCH); // partial tail
        let fused: Vec<Batch> = loader(fetch, 12, false).epoch(0).collect();
        assert!(fused.iter().all(|b| b.is_pooled()));
        assert_batches_identical(&legacy, &fused, fetch.label());
    }
}

#[test]
fn fused_assembly_is_byte_identical_under_work_stealing() {
    for fetch in FetchImpl::all() {
        let legacy: Vec<Batch> = loader(fetch, 0, false).epoch(0).collect();
        let fused: Vec<Batch> = loader(fetch, 12, true).epoch(0).collect();
        assert_batches_identical(&legacy, &fused, fetch.label());
    }
}

#[test]
fn recycled_slabs_stay_byte_identical_across_epochs() {
    // one fused loader reusing its slab pool for three epochs must keep
    // matching a fresh legacy loader epoch by epoch — any stale-slot or
    // truncation bug in the recycle path shows up here
    let fused_dl = loader(FetchImpl::Threaded, 10, true);
    for epoch in 0..3 {
        let legacy: Vec<Batch> =
            loader(FetchImpl::Threaded, 0, false).epoch(epoch).collect();
        let fused: Vec<Batch> = fused_dl.epoch(epoch).collect();
        assert_batches_identical(&legacy, &fused, &format!("epoch {epoch}"));
        for b in fused {
            b.recycle();
        }
    }
    let stats = fused_dl.arena().unwrap().stats();
    assert!(stats.reused > 0, "{stats:?}");
    assert_eq!(stats.checkouts, 15, "{stats:?}"); // 5 batches × 3 epochs
}

#[test]
fn item_steal_assembly_is_byte_identical_for_every_fetcher() {
    // item-granular dispatch (slots filled by whichever worker claims
    // them) must not change a single byte, label, index, or raw count
    for fetch in FetchImpl::all() {
        let legacy: Vec<Batch> = loader(fetch, 0, false).epoch(0).collect();
        let dl = Dataloader::new(
            dataset(),
            DataloaderConfig {
                batch_size: BATCH,
                num_workers: 3,
                fetch_impl: fetch,
                num_fetch_workers: 4,
                arena_slabs: 12,
                work_stealing: true,
                steal_items: true,
                consumer_credit: 3,
                spawn_cost_override: Some(Duration::ZERO),
                ..Default::default()
            },
            Recorder::new(),
        );
        let fused: Vec<Batch> = dl.epoch(0).collect();
        assert!(fused.iter().all(|b| b.is_pooled()), "{}", fetch.label());
        assert_batches_identical(&legacy, &fused, &format!("item-steal {}", fetch.label()));
    }
}

#[test]
fn dirstore_get_into_pipeline_matches_memstore_legacy() {
    // same corpus spec written to real files: the fused loader reads it
    // through the zero-copy get_into path and must produce the same
    // batches as the legacy MemStore loader
    let root = std::env::temp_dir().join(format!(
        "cdl-hotpath-dirstore-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&root);
    let dir: Arc<dyn ObjectStore> =
        Arc::new(cdl::storage::DirStore::open(&root).unwrap());
    generate_corpus(&dir, &CorpusSpec::tiny(ITEMS)).unwrap();
    let ds: Arc<dyn Dataset> = Arc::new(ImageFolderDataset::new(
        dir,
        AugmentConfig { crop: 16, ..Default::default() },
    ));
    let legacy: Vec<Batch> = loader(FetchImpl::Threaded, 0, false).epoch(0).collect();
    let dl = Dataloader::new(
        ds,
        DataloaderConfig {
            batch_size: BATCH,
            num_workers: 3,
            fetch_impl: FetchImpl::Threaded,
            num_fetch_workers: 4,
            arena_slabs: 12,
            work_stealing: true,
            spawn_cost_override: Some(Duration::ZERO),
            ..Default::default()
        },
        Recorder::new(),
    );
    let fused: Vec<Batch> = dl.epoch(0).collect();
    assert_batches_identical(&legacy, &fused, "dirstore get_into");
    let _ = std::fs::remove_dir_all(&root);
}

/// Store wrapper that stalls chosen keys — an adversarial straggler
/// schedule for the credit/backpressure stress below.
struct StragglerStore {
    inner: Arc<dyn ObjectStore>,
    /// stall every key whose (sorted) position is ≡ 0 mod this
    every: usize,
    delay: Duration,
    slow_keys: Vec<String>,
}

impl StragglerStore {
    fn new(inner: Arc<dyn ObjectStore>, every: usize, delay: Duration) -> StragglerStore {
        let slow_keys = inner.keys().into_iter().step_by(every).collect();
        StragglerStore { inner, every, delay, slow_keys }
    }
}

impl ObjectStore for StragglerStore {
    fn get(&self, key: &str) -> anyhow::Result<Bytes> {
        if self.slow_keys.iter().any(|k| k == key) {
            std::thread::sleep(self.delay);
        }
        self.inner.get(key)
    }

    fn put(&self, key: &str, data: Vec<u8>) -> anyhow::Result<()> {
        self.inner.put(key, data)
    }

    fn keys(&self) -> Vec<String> {
        self.inner.keys()
    }

    fn label(&self) -> String {
        format!("straggler(1/{} × {:?})", self.every, self.delay)
    }

    fn stats(&self) -> StoreStats {
        self.inner.stats()
    }
}

#[test]
fn reorder_buffer_never_exceeds_credit_under_adversarial_stragglers() {
    const CREDIT: usize = 2;
    let mem: Arc<dyn ObjectStore> = Arc::new(MemStore::new("m"));
    generate_corpus(&mem, &CorpusSpec::tiny(ITEMS)).unwrap();
    let slow: Arc<dyn ObjectStore> =
        Arc::new(StragglerStore::new(mem, 7, Duration::from_millis(25)));
    let ds: Arc<dyn Dataset> = Arc::new(ImageFolderDataset::new(
        slow,
        AugmentConfig { crop: 16, ..Default::default() },
    ));
    for fetch in FetchImpl::all() {
        for (work_stealing, steal_items) in [(false, false), (true, false), (true, true)] {
            let dl = Dataloader::new(
                ds.clone(),
                DataloaderConfig {
                    batch_size: BATCH,
                    num_workers: 3,
                    fetch_impl: fetch,
                    num_fetch_workers: 4,
                    arena_slabs: 10,
                    work_stealing,
                    steal_items,
                    consumer_credit: CREDIT,
                    spawn_cost_override: Some(Duration::ZERO),
                    ..Default::default()
                },
                Recorder::new(),
            );
            let ctx = format!(
                "{} stealing={work_stealing} items={steal_items}",
                fetch.label()
            );
            let mut it = dl.epoch(0);
            let mut ids = Vec::new();
            let mut seen = Vec::new();
            for b in it.by_ref() {
                ids.push(b.id);
                seen.extend(b.indices.iter().copied());
                b.recycle();
            }
            let hwm = it.reorder_high_water();
            drop(it);
            assert_eq!(ids, (0..5).collect::<Vec<_>>(), "{ctx}");
            seen.sort_unstable();
            assert_eq!(seen, (0..ITEMS).collect::<Vec<_>>(), "{ctx}");
            assert!(hwm <= CREDIT, "{ctx}: reorder hwm {hwm} > credit {CREDIT}");
        }
    }
}

#[test]
fn inline_loader_fused_matches_legacy() {
    let mk = |arena_slabs| {
        Dataloader::new(
            dataset(),
            DataloaderConfig {
                batch_size: BATCH,
                num_workers: 0, // inline in the consumer
                arena_slabs,
                ..Default::default()
            },
            Recorder::new(),
        )
    };
    let legacy: Vec<Batch> = mk(0).epoch(0).collect();
    let fused: Vec<Batch> = mk(4).epoch(0).collect();
    assert!(fused.iter().all(|b| b.is_pooled()));
    assert_batches_identical(&legacy, &fused, "inline");
}

#[test]
fn fused_batch_pool_disassembly_matches_legacy() {
    let mk = |arena_slabs| {
        Dataloader::new(
            dataset(),
            DataloaderConfig {
                batch_size: BATCH,
                num_workers: 2,
                fetch_impl: FetchImpl::Threaded,
                num_fetch_workers: 8,
                batch_pool: 2 * BATCH, // two batches per wave
                arena_slabs,
                spawn_cost_override: Some(Duration::ZERO),
                ..Default::default()
            },
            Recorder::new(),
        )
    };
    let legacy: Vec<Batch> = mk(0).epoch(0).collect();
    let fused: Vec<Batch> = mk(12).epoch(0).collect();
    assert_batches_identical(&legacy, &fused, "batch_pool");
}
