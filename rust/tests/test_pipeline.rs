//! Cross-module integration tests: loader × storage × fetcher matrix,
//! cache semantics under training, backpressure, failure injection
//! (corrupt objects), pinning, and shard loaders vs map-style content.

use std::sync::Arc;
use std::time::Duration;

use cdl::data::synth::{generate_corpus, generate_image, CorpusSpec};
use cdl::data::AugmentConfig;
use cdl::dataloader::{Dataloader, DataloaderConfig, FetchImpl, StartMethod};
use cdl::dataset::{Dataset, ImageFolderDataset};
use cdl::gil::Gil;
use cdl::shards::{build_shards, WebDatasetLoader};
use cdl::storage::{
    MemStore, ObjectStore, RemoteProfile, SimRemoteStore, VarnishCache,
};
use cdl::telemetry::Recorder;

fn corpus(items: usize) -> Arc<dyn ObjectStore> {
    let m: Arc<dyn ObjectStore> = Arc::new(MemStore::new("c"));
    generate_corpus(&m, &CorpusSpec::tiny(items)).unwrap();
    m
}

fn loader_over(
    store: Arc<dyn ObjectStore>,
    imp: FetchImpl,
    workers: usize,
    batch: usize,
) -> Dataloader {
    let ds: Arc<dyn Dataset> = Arc::new(ImageFolderDataset::new(
        store,
        AugmentConfig { crop: 16, ..Default::default() },
    ));
    Dataloader::new(
        ds,
        DataloaderConfig {
            batch_size: batch,
            num_workers: workers,
            fetch_impl: imp,
            num_fetch_workers: 8,
            spawn_cost_override: Some(Duration::ZERO),
            ..Default::default()
        },
        Recorder::new(),
    )
}

/// Every (storage, fetcher, workers) combination must deliver exactly
/// the dataset, once, in batch-id order, with correct labels.
#[test]
fn loader_storage_matrix_delivers_exact_multiset() {
    let profiles: [Option<RemoteProfile>; 2] =
        [None, Some(RemoteProfile::s3().scaled(0.02))];
    for profile in profiles {
        for imp in FetchImpl::all() {
            for workers in [1usize, 3] {
                let base = corpus(26);
                let store: Arc<dyn ObjectStore> = match &profile {
                    Some(p) => SimRemoteStore::new(base, p.clone(), 1),
                    None => base,
                };
                let dl = loader_over(store, imp, workers, 4);
                let batches: Vec<_> = dl.epoch(0).collect();
                assert_eq!(batches.len(), 7, "{imp:?} w{workers}");
                let ids: Vec<usize> = batches.iter().map(|b| b.id).collect();
                assert_eq!(ids, (0..7).collect::<Vec<_>>());
                let mut idxs: Vec<usize> = batches
                    .iter()
                    .flat_map(|b| b.indices.iter().copied())
                    .collect();
                idxs.sort_unstable();
                assert_eq!(idxs, (0..26).collect::<Vec<_>>());
                for b in &batches {
                    for (pos, &idx) in b.indices.iter().enumerate() {
                        assert_eq!(
                            b.labels[pos] as usize,
                            idx % 512,
                            "label mismatch at idx {idx}"
                        );
                    }
                }
            }
        }
    }
}

/// Batch pixels must be identical across fetcher strategies (same seed,
/// same epoch ⇒ same augmented pixels, regardless of fetch order).
#[test]
fn fetchers_produce_identical_pixels() {
    let mk = |imp| -> Vec<cdl::dataloader::Batch> {
        loader_over(corpus(12), imp, 2, 4).epoch(0).collect()
    };
    let vanilla = mk(FetchImpl::Vanilla);
    let threaded = mk(FetchImpl::Threaded);
    let asyncio = mk(FetchImpl::Asyncio);
    for (a, b) in vanilla.iter().zip(&threaded) {
        assert_eq!(a.indices, b.indices);
        assert_eq!(a.images.data, b.images.data, "threaded pixel mismatch");
    }
    for (a, b) in vanilla.iter().zip(&asyncio) {
        assert_eq!(a.images.data, b.images.data, "asyncio pixel mismatch");
    }
}

/// The data queue must respect the prefetch bound: with a stalled
/// consumer, only queue-capacity + in-flight batches may be fetched.
#[test]
fn backpressure_bounds_prefetch() {
    let store = corpus(64);
    let ds: Arc<dyn Dataset> = Arc::new(ImageFolderDataset::new(
        store,
        AugmentConfig { crop: 16, ..Default::default() },
    ));
    let rec = Recorder::new();
    let dl = Dataloader::new(
        ds,
        DataloaderConfig {
            batch_size: 4,
            num_workers: 2,
            prefetch_factor: 2,
            fetch_impl: FetchImpl::Vanilla,
            spawn_cost_override: Some(Duration::ZERO),
            ..Default::default()
        },
        rec.clone(),
    );
    let mut it = dl.epoch(0);
    let _first = it.next().unwrap();
    std::thread::sleep(Duration::from_millis(300));
    let fetched_items = rec.durations("get_item").len();
    // queue cap = 4 batches (16 items) + ≤1 in-flight per worker (8)
    // + the consumed batch (4) + reorder buffer slack (8)
    let bound = 16 + 8 + 4 + 8;
    assert!(
        fetched_items <= bound,
        "prefetched {fetched_items} items > bound {bound}"
    );
    drop(it);
}

/// Cache in front of a remote store: epoch 2 must be mostly hits and
/// clearly faster.
#[test]
fn cache_accelerates_second_epoch() {
    let base = corpus(16);
    let remote: Arc<dyn ObjectStore> =
        SimRemoteStore::new(base, RemoteProfile::s3().scaled(0.05), 2);
    let cache = VarnishCache::new(remote, u64::MAX / 2);
    let dl = loader_over(cache.clone(), FetchImpl::Vanilla, 2, 4);
    let t0 = std::time::Instant::now();
    assert_eq!(dl.epoch(0).count(), 4);
    let first = t0.elapsed();
    let t0 = std::time::Instant::now();
    assert_eq!(dl.epoch(1).count(), 4);
    let second = t0.elapsed();
    assert!(cache.hit_ratio() >= 0.49, "hit ratio {}", cache.hit_ratio());
    assert!(
        second < first / 2,
        "cached epoch {second:?} not ≪ cold epoch {first:?}"
    );
}

/// Failure injection: a corrupt object must not be silently delivered.
#[test]
fn corrupt_object_is_not_silently_delivered() {
    let m: Arc<dyn ObjectStore> = Arc::new(MemStore::new("c"));
    generate_corpus(&m, &CorpusSpec::tiny(8)).unwrap();
    let keys = m.keys();
    let mut buf = m.get(&keys[3]).unwrap().to_vec();
    let last = buf.len() - 1;
    buf[last] ^= 0xFF;
    m.put(&keys[3], buf).unwrap();

    let dl = loader_over(m, FetchImpl::Vanilla, 1, 4);
    let batches: Vec<_> = dl.epoch(0).collect();
    let delivered: Vec<usize> = batches
        .iter()
        .flat_map(|b| b.indices.iter().copied())
        .collect();
    // the batch containing item 3 is dropped (logged), the rest intact
    assert!(delivered.len() < 8, "corrupt item batch was delivered");
    assert!(batches.iter().all(|b| b.len() == 4));
}

/// spawn + pin_memory ⇒ batches arrive pinned.
#[test]
fn pinned_batches_flagged_under_spawn() {
    let store = corpus(8);
    let ds: Arc<dyn Dataset> = Arc::new(ImageFolderDataset::new(
        store,
        AugmentConfig { crop: 16, ..Default::default() },
    ));
    let dl = Dataloader::new(
        ds,
        DataloaderConfig {
            batch_size: 4,
            num_workers: 1,
            pin_memory: true,
            start_method: StartMethod::Spawn,
            spawn_cost_override: Some(Duration::ZERO),
            ..Default::default()
        },
        Recorder::new(),
    );
    let batches: Vec<_> = dl.epoch(0).collect();
    assert_eq!(batches.len(), 2);
    assert!(batches.iter().all(|b| b.pinned));
}

/// WebDataset shards deliver the same label multiset as per-item reads.
#[test]
fn shard_loader_content_matches_map_dataset() {
    let src = corpus(10);
    let shards: Arc<dyn ObjectStore> = Arc::new(MemStore::new("s"));
    let keys = build_shards(&src, &shards, 2).unwrap();
    let aug = AugmentConfig { crop: 16, ..Default::default() };
    let wds = WebDatasetLoader::new(shards, keys, aug);
    let gil = Gil::native();
    let mut labels_stream = Vec::new();
    wds.epoch(0, &gil, |s| labels_stream.push(s.label)).unwrap();
    assert_eq!(labels_stream.len(), 10);
    let spec = CorpusSpec::tiny(10);
    let mut want: Vec<u16> =
        (0..10).map(|i| generate_image(&spec, i).label).collect();
    let mut got = labels_stream;
    want.sort_unstable();
    got.sort_unstable();
    assert_eq!(got, want);
}

/// Asyncio loader on a 1-thread event loop must overlap remote latency
/// across items of a batch (the paper's core claim, end to end).
#[test]
fn asyncio_loader_overlaps_latency_end_to_end() {
    let mk = |imp| {
        let base = corpus(16);
        let store: Arc<dyn ObjectStore> =
            SimRemoteStore::new(base, RemoteProfile::s3().scaled(0.1), 3);
        let dl = loader_over(store, imp, 1, 8);
        let t0 = std::time::Instant::now();
        assert_eq!(dl.epoch(0).count(), 2);
        t0.elapsed().as_secs_f64()
    };
    let vanilla = mk(FetchImpl::Vanilla);
    let asyncio = mk(FetchImpl::Asyncio);
    assert!(
        asyncio < 0.5 * vanilla,
        "asyncio {asyncio:.2}s not ≪ vanilla {vanilla:.2}s"
    );
}
