//! Property-based tests (in-tree `util::prop` harness) on the
//! coordinator invariants: batching partitions, round-robin routing,
//! order restoration after disassembly, sampler permutations, LRU cache
//! capacity/accounting, token-bucket (Link) conservation, and stats
//! bounds.

use std::sync::Arc;

use cdl::dataloader::collate::restore_order;
use cdl::dataloader::sampler::{batches, BatchTicket, Sampler};
use cdl::simnet::Link;
use cdl::storage::{MemStore, ObjectStore, VarnishCache};
use cdl::util::prop::{check, gen, shrink_vec};
use cdl::util::rng::Rng;
use cdl::util::stats;

#[test]
fn prop_batching_partitions_order() {
    check(
        "batching partitions the order exactly",
        |rng| {
            let n = rng.below(500);
            let bs = rng.range(1, 64);
            (n, bs)
        },
        |&(n, bs)| {
            let order: Vec<usize> = (0..n).collect();
            let bs_list = batches(&order, bs, false);
            let flat: Vec<usize> = bs_list.iter().flatten().copied().collect();
            if flat != order {
                return Err("concatenated batches != order".into());
            }
            if bs_list.iter().rev().skip(1).any(|b| b.len() != bs) {
                return Err("non-final batch with wrong size".into());
            }
            if let Some(last) = bs_list.last() {
                if last.is_empty() || last.len() > bs {
                    return Err("bad final batch size".into());
                }
            }
            // drop_last variant only removes a partial tail
            let dropped = batches(&order, bs, true);
            if dropped.iter().any(|b| b.len() != bs) {
                return Err("drop_last left a partial batch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_ticket_stream_continuous_and_round_robin_balanced() {
    check(
        "ticketed epochs form a continuous stream; id % w routing balanced ±1",
        |rng| {
            let n_batches = rng.below(200);
            let workers = rng.range(1, 16);
            (n_batches, workers)
        },
        |&(n_batches, workers)| {
            let plan = |_e: usize| -> Vec<Vec<usize>> {
                (0..n_batches).map(|i| vec![i]).collect()
            };
            let e0 = BatchTicket::plan(0, 0, plan(0));
            let e1 = BatchTicket::plan(1, e0.len(), plan(1));
            // global seqs are continuous across the epoch seam
            let seqs: Vec<usize> = e0.iter().chain(&e1).map(|t| t.seq).collect();
            if seqs != (0..2 * n_batches).collect::<Vec<_>>() {
                return Err("seqs not continuous across the seam".into());
            }
            // per-epoch ids restart at 0 and cover the plan; epoch tags
            // ride every ticket
            for (e, tickets) in [(0usize, &e0), (1, &e1)] {
                let ids: Vec<usize> = tickets.iter().map(|t| t.id).collect();
                if ids != (0..n_batches).collect::<Vec<_>>() {
                    return Err(format!("epoch {e}: ids lost or duplicated"));
                }
                if tickets.iter().any(|t| t.epoch != e) {
                    return Err(format!("epoch {e}: wrong epoch tag"));
                }
            }
            // the static sink routes ticket id → worker id % w (torch's
            // rule, per epoch): balanced ±1
            if workers > 0 && n_batches > 0 {
                let mut counts = vec![0usize; workers];
                for t in &e0 {
                    counts[t.id % workers] += 1;
                }
                let (min, max) = (
                    counts.iter().min().copied().unwrap_or(0),
                    counts.iter().max().copied().unwrap_or(0),
                );
                if max - min > 1 {
                    return Err(format!("unbalanced: {counts:?}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_restore_order_inverts_any_arrival_permutation() {
    check(
        "restore_order inverts arrival shuffles",
        |rng| {
            let n = rng.range(1, 64);
            let perm = {
                let mut r = rng.fork(1);
                r.permutation(n)
            };
            (n, perm)
        },
        |&(n, ref perm)| {
            // fabricate samples whose index encodes their position
            let fetched: Vec<(usize, cdl::dataset::Sample)> = perm
                .iter()
                .map(|&pos| {
                    (
                        pos,
                        cdl::dataset::Sample {
                            index: 1000 + pos,
                            label: 0,
                            crop: cdl::data::U8Tensor::zeros(&[1, 1, 3]),
                            raw_bytes: 0,
                            fetch_time: 0.0,
                            decode_time: 0.0,
                        },
                    )
                })
                .collect();
            let sorted = restore_order(n, fetched);
            for (pos, s) in sorted.iter().enumerate() {
                if s.index != 1000 + pos {
                    return Err(format!("position {pos} holds {}", s.index));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_random_sampler_is_permutation() {
    check(
        "random sampler yields a permutation for any (n, epoch, seed)",
        |rng| (rng.below(300), rng.below(10), rng.next_u64()),
        |&(n, epoch, seed)| {
            let order = Sampler::Random { seed }.order(n, epoch);
            let mut sorted = order.clone();
            sorted.sort_unstable();
            if sorted != (0..n).collect::<Vec<_>>() {
                return Err("not a permutation".into());
            }
            // determinism
            if order != (Sampler::Random { seed }).order(n, epoch) {
                return Err("not deterministic".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_lru_cache_never_exceeds_capacity_and_accounts() {
    check_cache_property();
}

fn check_cache_property() {
    check(
        "LRU cache: bytes ≤ capacity; gets = hits + misses",
        |rng| {
            let capacity = rng.range(100, 2000) as u64;
            let accesses = gen::usize_vec(rng, 30, 120);
            let sizes: Vec<usize> =
                (0..30).map(|_| rng.range(10, 400)).collect();
            (capacity, accesses, sizes)
        },
        |(capacity, accesses, sizes)| {
            let mem = Arc::new(MemStore::new("b"));
            for (i, sz) in sizes.iter().enumerate() {
                mem.put(&format!("k{i}"), vec![0u8; *sz]).unwrap();
            }
            let cache = VarnishCache::new(mem, *capacity);
            for &a in accesses {
                cache.get(&format!("k{a}")).unwrap();
                if cache.cached_bytes() > *capacity {
                    return Err(format!(
                        "cache {} > cap {capacity}",
                        cache.cached_bytes()
                    ));
                }
            }
            let s = cache.stats();
            if s.gets != s.hits + s.misses {
                return Err(format!("{s:?}: gets != hits+misses"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_link_reservations_conserve_time() {
    check(
        "link FIFO: total wait ≥ sum(bytes)/rate for back-to-back reserves",
        |rng| {
            let mbit = rng.uniform(1.0, 1000.0);
            let sizes: Vec<usize> =
                (0..rng.range(1, 20)).map(|_| rng.range(1, 1 << 20)).collect();
            (mbit, sizes)
        },
        |(mbit, sizes)| {
            let link = Link::new_mbit_s(*mbit);
            let total_bytes: usize = sizes.iter().sum();
            let mut last_wait = std::time::Duration::ZERO;
            for &s in sizes {
                last_wait = link.reserve(s as u64);
            }
            let floor = total_bytes as f64 / (mbit * 1024.0 * 1024.0 / 8.0);
            // the last reservation completes no earlier than the serialized sum
            if last_wait.as_secs_f64() < floor * 0.95 {
                return Err(format!(
                    "last wait {:.4}s < serialized floor {floor:.4}s",
                    last_wait.as_secs_f64()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_percentiles_bounded_and_monotone() {
    check(
        "percentiles lie in [min,max] and are monotone in p",
        |rng| gen::pos_f64_vec(rng, 200),
        |xs| {
            if xs.is_empty() {
                return Ok(());
            }
            let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
            let hi = xs.iter().cloned().fold(0.0, f64::max);
            let mut prev = f64::NEG_INFINITY;
            for p in [0.0, 10.0, 50.0, 90.0, 100.0] {
                let v = stats::percentile(xs, p);
                if v < lo - 1e-9 || v > hi + 1e-9 {
                    return Err(format!("p{p} = {v} outside [{lo}, {hi}]"));
                }
                if v < prev - 1e-12 {
                    return Err(format!("p{p} not monotone"));
                }
                prev = v;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_shrinker_example_tar_roundtrip() {
    // round-trip tar for arbitrary entry size vectors, with shrinking
    cdl::util::prop::check_shrink(
        "tar roundtrip for arbitrary sizes",
        |rng| gen::usize_vec(rng, 3000, 12),
        shrink_vec,
        |sizes| {
            let entries: Vec<cdl::shards::TarEntry> = sizes
                .iter()
                .enumerate()
                .map(|(i, &s)| cdl::shards::TarEntry {
                    name: format!("e{i}.bin"),
                    data: vec![(i % 251) as u8; s],
                })
                .collect();
            let tar = cdl::shards::write_tar(&entries).map_err(|e| e.to_string())?;
            let back = cdl::shards::read_tar(&tar).map_err(|e| e.to_string())?;
            if back != entries {
                return Err("tar roundtrip mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_json_roundtrip_random_tables() {
    check(
        "json roundtrip of random benchmark-report-shaped docs",
        |rng: &mut Rng| {
            let mut obj = cdl::util::json::Json::obj();
            for i in 0..rng.below(12) {
                match rng.below(3) {
                    0 => obj.set(&format!("k{i}"), rng.f64()),
                    1 => obj.set(&format!("k{i}"), format!("v{}", rng.next_u32())),
                    _ => obj.set(
                        &format!("k{i}"),
                        (0..rng.below(5))
                            .map(|j| j as f64)
                            .collect::<Vec<f64>>(),
                    ),
                };
            }
            obj
        },
        |doc| {
            let text = doc.pretty();
            let back = cdl::util::json::parse(&text).map_err(|e| e.to_string())?;
            if &back != doc {
                return Err("json roundtrip mismatch".into());
            }
            Ok(())
        },
    );
}
