//! Cross-epoch pipelining correctness (PR 5): pipelined
//! (`epoch_pipeline = 1`) multi-epoch runs are **byte-identical** to
//! legacy drained runs for every fetcher × dispatch mode under the
//! shuffled sampler; the consumer-credit bound holds *through* the
//! epoch seam (the reorder high-water counts early next-epoch
//! arrivals); and an epoch-N straggler holding a stale arena builder
//! can never scribble on an epoch-N+1 slab.

use std::sync::Arc;
use std::time::Duration;

use cdl::data::synth::{generate_corpus, CorpusSpec};
use cdl::data::AugmentConfig;
use cdl::dataloader::{Batch, BatchArena, Dataloader, DataloaderConfig, FetchImpl};
use cdl::dataset::{Dataset, ImageFolderDataset, ItemMeta};
use cdl::storage::{Bytes, MemStore, ObjectStore, StoreStats};
use cdl::telemetry::Recorder;

const ITEMS: usize = 37; // not a multiple of the batch size: partial tail
const BATCH: usize = 8;
const EPOCHS: usize = 3;

fn dataset() -> Arc<dyn Dataset> {
    let mem: Arc<dyn ObjectStore> = Arc::new(MemStore::new("m"));
    generate_corpus(&mem, &CorpusSpec::tiny(ITEMS)).unwrap();
    Arc::new(ImageFolderDataset::new(
        mem,
        AugmentConfig { crop: 16, ..Default::default() },
    ))
}

/// (work_stealing, steal_items) per dispatch mode.
const DISPATCH: [(bool, bool); 3] = [(false, false), (true, false), (true, true)];

fn loader(
    ds: &Arc<dyn Dataset>,
    fetch: FetchImpl,
    (work_stealing, steal_items): (bool, bool),
    epoch_pipeline: usize,
) -> Dataloader {
    Dataloader::new(
        ds.clone(),
        DataloaderConfig {
            batch_size: BATCH,
            num_workers: 3,
            fetch_impl: fetch,
            num_fetch_workers: 4,
            arena_slabs: 12,
            work_stealing,
            steal_items,
            consumer_credit: 3,
            epoch_pipeline,
            spawn_cost_override: Some(Duration::ZERO),
            ..Default::default()
        },
        Recorder::new(),
    )
}

fn assert_batches_identical(drained: &[Batch], pipelined: &[Batch], ctx: &str) {
    assert_eq!(drained.len(), pipelined.len(), "{ctx}: batch count");
    for (a, b) in drained.iter().zip(pipelined.iter()) {
        assert_eq!(a.id, b.id, "{ctx}");
        assert_eq!(a.images.shape, b.images.shape, "{ctx}: batch {}", a.id);
        assert_eq!(a.images.data, b.images.data, "{ctx}: batch {} bytes", a.id);
        assert_eq!(a.labels, b.labels, "{ctx}: batch {}", a.id);
        assert_eq!(a.indices, b.indices, "{ctx}: batch {}", a.id);
        assert_eq!(a.raw_bytes, b.raw_bytes, "{ctx}: batch {}", a.id);
    }
}

#[test]
fn pipelined_multi_epoch_runs_are_byte_identical_to_drained() {
    // shuffled sampler (the default) × every fetcher × every dispatch
    // mode: the same persistent loader run for three epochs must emit
    // the exact same batches whether the boundary drains or pipelines —
    // the epoch tag travels with every item load, so a worker decoding
    // epoch N+1's head while N's tail delivers uses N+1's augment seed
    let ds = dataset();
    for fetch in FetchImpl::all() {
        for dispatch in DISPATCH {
            let drained = loader(&ds, fetch, dispatch, 0);
            let pipelined = loader(&ds, fetch, dispatch, 1);
            for epoch in 0..EPOCHS {
                let a: Vec<Batch> = drained.epoch(epoch).collect();
                let b: Vec<Batch> = pipelined.epoch(epoch).collect();
                assert_eq!(a.last().unwrap().len(), ITEMS % BATCH); // partial tail
                assert_batches_identical(
                    &a,
                    &b,
                    &format!("{} {dispatch:?} epoch {epoch}", fetch.label()),
                );
                for batch in a.into_iter().chain(b) {
                    batch.recycle();
                }
            }
            // the pipelined loader actually ran ahead of the consumer:
            // a drained worker pre-publishes epoch EPOCHS's plan (the
            // publication is asynchronous, so poll briefly)
            let deadline = std::time::Instant::now() + Duration::from_secs(5);
            while pipelined.plans_published() <= EPOCHS
                && std::time::Instant::now() < deadline
            {
                std::thread::sleep(Duration::from_millis(1));
            }
            assert!(
                pipelined.plans_published() > EPOCHS,
                "{}: no plan was pre-published (pipelining never engaged)",
                fetch.label()
            );
            assert_eq!(drained.plans_published(), EPOCHS, "{}", fetch.label());
        }
    }
}

/// Store wrapper that stalls chosen keys — an adversarial straggler
/// schedule for the cross-epoch credit stress below.
struct StragglerStore {
    inner: Arc<dyn ObjectStore>,
    every: usize,
    delay: Duration,
    slow_keys: Vec<String>,
}

impl StragglerStore {
    fn new(inner: Arc<dyn ObjectStore>, every: usize, delay: Duration) -> StragglerStore {
        let slow_keys = inner.keys().into_iter().step_by(every).collect();
        StragglerStore { inner, every, delay, slow_keys }
    }
}

impl ObjectStore for StragglerStore {
    fn get(&self, key: &str) -> anyhow::Result<Bytes> {
        if self.slow_keys.iter().any(|k| k == key) {
            std::thread::sleep(self.delay);
        }
        self.inner.get(key)
    }

    fn put(&self, key: &str, data: Vec<u8>) -> anyhow::Result<()> {
        self.inner.put(key, data)
    }

    fn keys(&self) -> Vec<String> {
        self.inner.keys()
    }

    fn label(&self) -> String {
        format!("straggler(1/{} × {:?})", self.every, self.delay)
    }

    fn stats(&self) -> StoreStats {
        self.inner.stats()
    }
}

#[test]
fn reorder_buffer_respects_credit_through_the_epoch_seam() {
    // credit 2, pipelining on: epoch N+1's head batches may finish
    // while N's straggling tail still delivers, but the through-seam
    // reorder buffer (which counts those early arrivals) must never
    // exceed the credit — the gate window is in global seqs
    const CREDIT: usize = 2;
    let mem: Arc<dyn ObjectStore> = Arc::new(MemStore::new("m"));
    generate_corpus(&mem, &CorpusSpec::tiny(ITEMS)).unwrap();
    let slow: Arc<dyn ObjectStore> =
        Arc::new(StragglerStore::new(mem, 7, Duration::from_millis(20)));
    let ds: Arc<dyn Dataset> = Arc::new(ImageFolderDataset::new(
        slow,
        AugmentConfig { crop: 16, ..Default::default() },
    ));
    for fetch in FetchImpl::all() {
        for dispatch in DISPATCH {
            let dl = Dataloader::new(
                ds.clone(),
                DataloaderConfig {
                    batch_size: BATCH,
                    num_workers: 3,
                    fetch_impl: fetch,
                    num_fetch_workers: 4,
                    arena_slabs: 10,
                    work_stealing: dispatch.0,
                    steal_items: dispatch.1,
                    consumer_credit: CREDIT,
                    epoch_pipeline: 1,
                    spawn_cost_override: Some(Duration::ZERO),
                    ..Default::default()
                },
                Recorder::new(),
            );
            for epoch in 0..EPOCHS {
                let ctx = format!("{} {dispatch:?} epoch {epoch}", fetch.label());
                let mut it = dl.epoch(epoch);
                let mut ids = Vec::new();
                let mut seen = Vec::new();
                for b in it.by_ref() {
                    ids.push(b.id);
                    seen.extend(b.indices.iter().copied());
                    b.recycle();
                }
                let hwm = it.reorder_high_water();
                drop(it);
                assert_eq!(ids, (0..5).collect::<Vec<_>>(), "{ctx}");
                seen.sort_unstable();
                assert_eq!(seen, (0..ITEMS).collect::<Vec<_>>(), "{ctx}");
                assert!(
                    hwm <= CREDIT,
                    "{ctx}: through-seam reorder hwm {hwm} > credit {CREDIT}"
                );
            }
        }
    }
}

#[test]
fn epoch_n_straggler_cannot_fill_an_epoch_n1_slab() {
    // the generation-tagged claim words: a builder clone left over from
    // epoch N (a straggling thief) must fail cleanly — naming both
    // epochs — once its slab has been recycled into epoch N+1, and the
    // new batch's bytes must be untouched
    let arena = BatchArena::new(4, 2, 2);
    let epoch0 = arena.clone().checkout_tagged(0, 0, 0, 2);
    let straggler = epoch0.clone();
    for pos in 0..2 {
        epoch0
            .fill(pos, pos, |out| {
                out.fill(1);
                Ok(ItemMeta { label: 0, raw_bytes: 1 })
            })
            .unwrap();
    }
    epoch0.finish().unwrap().recycle();

    // same slab, next epoch (seq continues on the global stream)
    let epoch1 = arena.clone().checkout_tagged(0, 5, 1, 2);
    assert_eq!(epoch1.epoch(), 1);
    assert_eq!(epoch1.seq(), 5);
    let err = straggler
        .fill(0, 9, |out| {
            out.fill(0xEE);
            Ok(ItemMeta { label: 0, raw_bytes: 1 })
        })
        .unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("stale builder"), "{msg}");
    assert!(msg.contains("epoch 0"), "{msg}");
    assert!(msg.contains("epoch 1"), "{msg}");

    for pos in 0..2 {
        epoch1
            .fill(pos, 10 + pos, |out| {
                out.fill(7);
                Ok(ItemMeta { label: 1, raw_bytes: 2 })
            })
            .unwrap();
    }
    let batch = epoch1.finish().unwrap();
    assert!(
        batch.images.data.iter().all(|&v| v == 7),
        "epoch-0 straggler scribbled on the epoch-1 slab"
    );
}

#[test]
fn pipelined_loader_over_prefetch_store_spans_epochs() {
    // rig-level: prefetch engine + epoch pipelining — the horizon
    // handoff (hint_order_append at plan publication) must keep the
    // engine serving demand across the seam, with every item of every
    // epoch delivered exactly once
    let mut spec = cdl::bench::rig::RigSpec::quick("s3", 0.02);
    spec.items = 48;
    spec.batch_size = 8;
    spec.num_workers = 3;
    spec.fetch_impl = FetchImpl::Threaded;
    spec.prefetch_depth = 24;
    spec.arena_slabs = 12;
    spec.work_stealing = true;
    spec.steal_items = true;
    spec.consumer_credit = 4;
    spec.epoch_pipeline = 1;
    let rig = cdl::bench::rig::build(&spec).unwrap();
    for epoch in 0..EPOCHS {
        let (_, _, n) = cdl::bench::rig::drain_numbered_epoch(&rig, epoch);
        assert_eq!(n, 6, "epoch {epoch}");
    }
    let p = rig.prefetch.as_ref().unwrap();
    let c = p.counters();
    assert_eq!(c.gets, (48 * EPOCHS) as u64, "{c:?}");
    assert!(c.issued > 0, "engine never speculated: {c:?}");
}
