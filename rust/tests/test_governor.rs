//! Governor control-loop correctness: deterministic convergence on a
//! simulated cost model, hysteresis under a noisy objective (no
//! keep-churn, no oscillation), hard bound enforcement along every
//! knob ladder, byte-identity of an autotuned run against a fixed run
//! across epoch seams, and the plan-revocation path — a
//! non-sequential `epoch()` request unpublishes the mispredicted
//! speculative plan instead of tearing the workers down.

use std::sync::Arc;
use std::time::Duration;

use cdl::data::synth::{generate_corpus, CorpusSpec};
use cdl::data::AugmentConfig;
use cdl::dataloader::{Batch, Dataloader, DataloaderConfig, FetchImpl};
use cdl::dataset::{Dataset, ImageFolderDataset};
use cdl::governor::{
    Action, Governor, GovernorConfig, Knob, KnobBounds, Signals, TunedKnobs,
};
use cdl::storage::{MemStore, ObjectStore};
use cdl::telemetry::{names, Recorder};

fn locked_except_prefetch(max: usize) -> KnobBounds {
    KnobBounds { prefetch_depth: Some((4, max)), ..KnobBounds::locked() }
}

/// Simulated cost model: batches/s rises with `prefetch_depth` up to a
/// knee at 64 and is flat past it. The Governor must climb the ladder
/// to the knee, then hold there (probing past it reverts).
#[test]
fn governor_converges_on_a_simulated_cost_model() {
    let cfg = DataloaderConfig { prefetch_depth: 8, ..Default::default() };
    let knobs = TunedKnobs::from_config(&cfg);
    let mut gov = Governor::new(
        GovernorConfig::default(),
        knobs.clone(),
        locked_except_prefetch(128),
    );
    let model_bps = |pf: usize| 10.0 + 5.0 * ((pf.min(64) as f64 / 8.0).log2());
    for epoch in 0..8 {
        knobs.commit(); // the epoch seam
        let pf = knobs.prefetch_depth();
        let bps = model_bps(pf);
        gov.end_epoch(&Signals {
            epoch,
            batches: 100,
            epoch_s: 100.0 / bps,
            // tier hit ratio saturates at the knee, directing probes
            prefetch_hit_ratio: (pf as f64 / 64.0).min(1.0),
            ..Default::default()
        });
    }
    knobs.commit();
    assert_eq!(knobs.prefetch_depth(), 64, "converged to the knee");
    let (probes, keeps, reverts) = gov.counts();
    assert_eq!(keeps, 3, "8 → 16 → 32 → 64 all kept");
    assert!(reverts >= 1, "the probe past the knee reverted");
    assert!(probes >= 4);
    let (bps, _) = gov.baseline();
    assert!(bps > 24.0, "baseline tracked the optimum, got {bps}");
}

/// A flat objective with deterministic ±2% noise (inside the 3% keep
/// margin) must never produce a keep: every probe reverts back to the
/// starting value, so the pipeline does not churn on noise.
#[test]
fn noisy_plateau_never_keeps_and_never_drifts() {
    let cfg = DataloaderConfig {
        num_workers: 4,
        arena_slabs: 16,
        consumer_credit: 4,
        ..Default::default()
    };
    let knobs = TunedKnobs::from_config(&cfg);
    let mut gov = Governor::new(
        GovernorConfig::default(),
        knobs.clone(),
        KnobBounds { credit: Some((2, 12)), ..KnobBounds::locked() },
    );
    // 17 epochs: probes fire every 3rd epoch (revert → 2-epoch
    // cooldown), so the last step is a decided revert, not an
    // in-flight probe
    for epoch in 0..17 {
        knobs.commit();
        let noise = if epoch % 2 == 0 { 1.02 } else { 0.98 };
        let bps = 20.0 * noise;
        gov.end_epoch(&Signals {
            epoch,
            batches: 100,
            epoch_s: 100.0 / bps,
            ..Default::default()
        });
    }
    let (probes, keeps, reverts) = gov.counts();
    assert_eq!(keeps, 0, "noise below the margin must not be kept");
    assert!(probes >= 2, "cooldown still lets the plateau be re-probed");
    assert_eq!(probes, reverts, "every probe reverted");
    for d in gov.decisions() {
        assert_ne!(d.action, Action::Keep);
        if d.action == Action::Revert {
            assert_eq!(d.to, 4, "reverts restore the starting credit");
        }
    }
    knobs.commit();
    assert_eq!(knobs.credit(), 4, "live value never drifted");
}

/// Staged values must stay inside the derived bounds on every epoch,
/// whatever the signals claim — the arena-budget credit cap, the
/// ladder ends, the worker count, the pipeline depth cap.
#[test]
fn staged_values_stay_within_bounds_under_adversarial_signals() {
    let cfg = DataloaderConfig {
        num_workers: 4,
        arena_slabs: 16,
        work_stealing: true,
        consumer_credit: 4,
        prefetch_depth: 8,
        io_depth: 8,
        ..Default::default()
    };
    let knobs = TunedKnobs::from_config(&cfg);
    let bounds = KnobBounds::derive(&cfg, true, true, true);
    let (cmin, cmax) = bounds.credit.unwrap();
    let mut gov = Governor::new(GovernorConfig::default(), knobs.clone(), bounds);
    for epoch in 0..40 {
        knobs.commit();
        // rising objective → every probe keeps, walking each ladder to
        // its end; signals rotate through every attribution rule
        let bps = 10.0 + epoch as f64;
        let epoch_s = 100.0 / bps;
        let mut sig = Signals {
            epoch,
            batches: 100,
            epoch_s,
            p99_batch_s: 0.0,
            ..Default::default()
        };
        match epoch % 6 {
            0 => sig.credit_blocked_s = 0.5 * epoch_s,
            1 => sig.ring_queued = 3,
            2 => sig.prefetch_hit_ratio = 0.1,
            3 => sig.seam_idle_s = 0.5 * epoch_s,
            4 => {
                sig.reorder_hwm = 6;
                sig.p99_batch_s = epoch_s; // ≫ mean batch
            }
            _ => {
                sig.decode_s = 10.0;
                sig.storage_wait_s = 0.1;
            }
        }
        gov.end_epoch(&sig);
        let credit = knobs.staged_credit();
        assert!(
            credit == 0 || (cmin..=cmax).contains(&credit),
            "epoch {epoch}: staged credit {credit} outside [{cmin}, {cmax}] ∪ {{0}}"
        );
        let pf = knobs.staged_prefetch_depth();
        assert!((4..=256).contains(&pf), "epoch {epoch}: prefetch {pf}");
        let io = knobs.staged_io_depth();
        assert!((4..=256).contains(&io), "epoch {epoch}: io_depth {io}");
        let aw = knobs.staged_active_workers();
        assert!((1..=4).contains(&aw), "epoch {epoch}: active_workers {aw}");
        assert!(knobs.staged_epoch_pipeline() <= 1, "epoch {epoch}: pipeline");
    }
    let (probes, keeps, _) = gov.counts();
    assert!(probes >= 10, "adversarial signals kept probing, got {probes}");
    assert!(keeps >= 5, "rising objective kept most probes, got {keeps}");
}

const ITEMS: usize = 33;
const BATCH: usize = 8;

fn dataset() -> Arc<dyn Dataset> {
    let mem: Arc<dyn ObjectStore> = Arc::new(MemStore::new("m"));
    generate_corpus(&mem, &CorpusSpec::tiny(ITEMS)).unwrap();
    Arc::new(ImageFolderDataset::new(
        mem,
        AugmentConfig { crop: 16, ..Default::default() },
    ))
}

fn loader(ds: &Arc<dyn Dataset>, epoch_pipeline: usize) -> Dataloader {
    Dataloader::new(
        ds.clone(),
        DataloaderConfig {
            batch_size: BATCH,
            num_workers: 3,
            fetch_impl: FetchImpl::Threaded,
            num_fetch_workers: 4,
            arena_slabs: 12,
            work_stealing: true,
            steal_items: false,
            consumer_credit: 4,
            epoch_pipeline,
            spawn_cost_override: Some(Duration::ZERO),
            ..Default::default()
        },
        Recorder::new(),
    )
}

fn assert_batches_identical(fixed: &[Batch], tuned: &[Batch], ctx: &str) {
    assert_eq!(fixed.len(), tuned.len(), "{ctx}: batch count");
    for (a, b) in fixed.iter().zip(tuned.iter()) {
        assert_eq!(a.id, b.id, "{ctx}");
        assert_eq!(a.images.data, b.images.data, "{ctx}: batch {} bytes", a.id);
        assert_eq!(a.labels, b.labels, "{ctx}: batch {}", a.id);
        assert_eq!(a.indices, b.indices, "{ctx}: batch {}", a.id);
    }
}

/// The autotuned loader — with the Governor widening credit, enabling
/// item stealing, and turning on epoch pipelining across successive
/// seams — must deliver byte-identical batches to a loader whose knobs
/// never move. Knob changes apply only at seams, so the epoch's
/// content and order cannot depend on them.
#[test]
fn autotuned_run_is_byte_identical_to_fixed_across_seams() {
    let ds = dataset();
    let fixed = loader(&ds, 0);
    let tuned = loader(&ds, 0);
    let knobs = tuned.knobs().clone();
    let mut gov = Governor::new(
        GovernorConfig::default(),
        knobs.clone(),
        KnobBounds {
            credit: Some((2, 9)),
            steal_items: true,
            epoch_pipeline: Some(1),
            ..KnobBounds::locked()
        },
    );
    for epoch in 0..5 {
        let a: Vec<Batch> = fixed.epoch(epoch).collect();
        let b: Vec<Batch> = tuned.epoch(epoch).collect();
        assert_batches_identical(&a, &b, &format!("epoch {epoch}"));
        for batch in a.into_iter().chain(b) {
            batch.recycle();
        }
        // hand-crafted signals with a rising objective: every probe is
        // kept, so the tuned loader's knob set really changes between
        // consecutive epochs
        let bps = 10.0 + 2.0 * epoch as f64;
        let epoch_s = 100.0 / bps;
        let mut sig =
            Signals { epoch, batches: 100, epoch_s, ..Default::default() };
        match epoch {
            0 => sig.credit_blocked_s = 0.5 * epoch_s,
            1 => sig.reorder_hwm = 6,
            _ => sig.seam_idle_s = 0.5 * epoch_s,
        }
        gov.end_epoch(&sig);
    }
    let (_, keeps, _) = gov.counts();
    assert!(keeps >= 2, "the tuned run must actually have moved knobs");
    let moved = knobs.credit() != 4
        || knobs.steal_items()
        || knobs.epoch_pipeline() != 0;
    assert!(moved, "at least one live knob changed across the seams");
    assert!(
        gov.decisions().iter().any(|d| d.action == Action::Keep
            && (d.knob == Knob::Credit
                || d.knob == Knob::StealItems
                || d.knob == Knob::EpochPipeline)),
        "kept decisions recorded in the log"
    );
}

/// Non-sequential `epoch()` under pipelining: the mispredicted
/// speculative plan is revoked in place — no worker teardown/respawn —
/// and the requested epoch's batches are byte-identical to a fresh
/// loader asked for the same epoch.
#[test]
fn nonsequential_epoch_revokes_plans_without_respawning_workers() {
    let ds = dataset();
    let dl = loader(&ds, 1);
    for epoch in 0..2 {
        for b in dl.epoch(epoch) {
            b.recycle();
        }
    }
    // wait for a worker to pre-publish the predicted epoch 2
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while dl.plans_published() <= 2 && std::time::Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(1));
    }
    assert!(dl.plans_published() > 2, "pipelining never engaged");
    let spawns = || {
        dl.recorder()
            .snapshot()
            .iter()
            .filter(|s| s.name == names::WORKER_SPAWN)
            .count()
    };
    let spawned_before = spawns();
    assert!(spawned_before > 0, "workers spawned during epochs 0-1");
    assert_eq!(dl.plans_revoked(), 0);

    // jump: the pre-published plan predicted epoch 2, the consumer
    // asks for epoch 5
    let jumped: Vec<Batch> = dl.epoch(5).collect();
    assert!(dl.plans_revoked() > 0, "the mispredicted plan was revoked");
    assert_eq!(
        spawns(),
        spawned_before,
        "revocation must not tear workers down"
    );
    assert!(
        dl.recorder().snapshot().iter().any(|s| s.name == names::PLAN_REVOKE),
        "revocation recorded as a span"
    );

    // the jumped epoch is byte-identical to a fresh loader's epoch 5
    let fresh = loader(&ds, 1);
    let reference: Vec<Batch> = fresh.epoch(5).collect();
    assert_batches_identical(&reference, &jumped, "epoch 5 after jump");
    for batch in jumped.into_iter().chain(reference) {
        batch.recycle();
    }

    // the pipeline still works sequentially after the jump
    let n = dl.epoch(6).map(|b| b.recycle()).count();
    assert_eq!(n, ITEMS.div_ceil(BATCH), "epoch 6 drains normally");
}
