//! Chaos-ready storage plane, end to end: seeded fault injection on the
//! simulated remote vs the resilience layer mounted above it.
//!
//! * **Seeded-chaos equivalence** — the same rig spec run fault-free
//!   and under the `flaky` profile behind `retry_max = 4` must deliver
//!   byte-identical batches across every fetcher shape: vanilla,
//!   threaded, work-stealing + item-steal, the pipelined epoch seam,
//!   the loader-side wave ring, and shard-window streaming with the
//!   ring under the facade. Faults never corrupt bytes and retries are
//!   transparent, so equality is exact, not statistical.
//! * **Deterministic budget arithmetic** — a 100%-fault profile with a
//!   `max_consecutive = 3` forced-success cap splits cleanly: a budget
//!   of 4 extra attempts drains every batch (3 retries per op), a
//!   budget of 1 exhausts every op and tombstones every batch, no
//!   panic either way.
//! * **Breaker lifecycle** — a hard outage opens the breaker and
//!   fast-fails demand reads; healing the injector and waiting out the
//!   cooldown lets the half-open probe through and closes it again.
//! * **Hedge + deadline plumbing** — a shard/ring rig with hedging and
//!   a generous deadline enabled stays byte-identical with zero
//!   deadline hits (hedge-cancellation accounting itself is pinned by
//!   the `storage::resilient` unit tests).

use std::time::Duration;

use cdl::bench::rig::{self, RigSpec};
use cdl::dataloader::FetchImpl;
use cdl::storage::FaultProfile;

/// All delivered batches of `epochs` consecutive epochs, in order.
fn collect_epochs(r: &rig::Rig, epochs: usize) -> Vec<(Vec<u8>, Vec<i32>)> {
    let mut out = Vec::new();
    for e in 0..epochs {
        for b in r.dataloader.epoch(e) {
            out.push((b.images.data.clone(), b.labels.clone()));
            b.recycle();
        }
    }
    out
}

#[test]
fn flaky_faults_behind_resilience_are_byte_transparent_everywhere() {
    let variants: Vec<(&str, fn(&mut RigSpec))> = vec![
        ("vanilla", |_| {}),
        ("threaded", |s| {
            s.fetch_impl = FetchImpl::Threaded;
        }),
        ("item-steal", |s| {
            s.fetch_impl = FetchImpl::Threaded;
            s.work_stealing = true;
            s.steal_items = true;
            s.arena_slabs = 16;
            s.consumer_credit = 4;
        }),
        ("pipelined-seam", |s| {
            s.fetch_impl = FetchImpl::Threaded;
            s.arena_slabs = 16;
            s.epoch_pipeline = 1;
        }),
        ("wave-ring", |s| {
            s.fetch_impl = FetchImpl::Threaded;
            s.arena_slabs = 16;
            s.io_depth = 32;
        }),
        ("shard-ring", |s| {
            s.fetch_impl = FetchImpl::Threaded;
            s.shard_size = 4;
            s.shard_shuffle = true;
            s.prefetch_depth = 4;
            s.io_depth = 32;
        }),
    ];
    let mut total_retries = 0u64;
    for (name, tweak) in variants {
        let mut clean = RigSpec::quick("s3", 0.02);
        clean.items = 24;
        clean.batch_size = 8;
        tweak(&mut clean);
        let mut chaos = clean.clone();
        chaos.fault_profile = "flaky";
        chaos.retry_max = 4;
        let a = rig::build(&clean).unwrap();
        let b = rig::build(&chaos).unwrap();
        let want = collect_epochs(&a, 2);
        let got = collect_epochs(&b, 2);
        assert_eq!(want.len(), 6, "{name}: clean rig lost batches");
        assert_eq!(got.len(), want.len(), "{name}: chaos rig lost batches");
        for (i, (w, g)) in want.iter().zip(&got).enumerate() {
            assert_eq!(w.0, g.0, "{name}: batch {i} bytes differ under chaos");
            assert_eq!(w.1, g.1, "{name}: batch {i} labels differ under chaos");
        }
        let s = b.resilient.as_ref().unwrap().snapshot();
        assert_eq!(s.exhausted, 0, "{name}: {s:?}");
        let f = b.faults.as_ref().unwrap().counters();
        assert!(f.decisions > 0, "{name}: injector never consulted");
        total_retries += s.retries;
    }
    // 0.2 error-fault rate over hundreds of remote reads: some variant
    // must have retried, or the whole suite is vacuous
    assert!(total_retries > 0, "no variant ever retried");
}

#[test]
fn retry_budget_arithmetic_is_deterministic() {
    // every request faults, but a key is forced to succeed on its 4th
    // consecutive attempt: independent of thread interleaving, a budget
    // of 4 extra attempts always drains and a budget of 1 never does
    let always = FaultProfile {
        error_rate: 1.0,
        stall_rate: 0.0,
        stall_ms: 0,
        reset_rate: 0.0,
        short_read_rate: 0.0,
        max_consecutive: 3,
    };
    let mut spec = RigSpec::quick("s3", 0.02);
    spec.items = 24;
    spec.batch_size = 8;
    spec.fault_profile = "flaky"; // attaches the injector; swapped below
    spec.retry_max = 4;
    let rich = rig::build(&spec).unwrap();
    rich.faults.as_ref().unwrap().set_profile(always);
    let (_, _, n) = rig::drain_epoch(&rich);
    assert_eq!(n, 3, "budget ≥ cap must deliver every batch");
    let s = rich.resilient.as_ref().unwrap().snapshot();
    assert_eq!(s.exhausted, 0, "{s:?}");
    assert!(s.retries >= 3 * 24, "3 forced retries per item: {s:?}");

    let mut thin = spec.clone();
    thin.retry_max = 1;
    let poor = rig::build(&thin).unwrap();
    poor.faults.as_ref().unwrap().set_profile(always);
    let (_, _, n) = rig::drain_epoch(&poor);
    assert_eq!(n, 0, "budget < cap must tombstone every batch");
    let s = poor.resilient.as_ref().unwrap().snapshot();
    assert!(s.exhausted > 0, "{s:?}");
    assert!(s.breaker_opens >= 1, "consecutive exhaustion must trip: {s:?}");
}

#[test]
fn breaker_opens_on_outage_and_closes_after_heal() {
    let mut spec = RigSpec::quick("s3", 0.02);
    spec.items = 16;
    spec.batch_size = 8;
    spec.fault_profile = "outage";
    spec.retry_max = 1;
    let rig = rig::build(&spec).unwrap();
    let (_, _, n) = rig::drain_epoch(&rig);
    assert_eq!(n, 0, "an outage delivers nothing");
    let rs = rig.resilient.as_ref().unwrap();
    let snap = rs.snapshot();
    assert!(snap.exhausted > 0, "{snap:?}");
    assert!(snap.breaker_opens >= 1, "{snap:?}");
    assert!(snap.breaker_fastfail > 0, "{snap:?}");
    // the backend is still dead: whatever the breaker admits fails
    let key = rig.store.keys().first().cloned().expect("corpus keys");
    assert!(rig.store.get(&key).is_err());
    // heal the backend and wait out the cooldown: the next demand read
    // is the half-open probe, and its success closes the breaker
    rig.faults.as_ref().unwrap().set_profile(FaultProfile::none());
    std::thread::sleep(Duration::from_millis(300));
    let bytes = rig.store.get(&key).expect("half-open probe must succeed");
    assert!(!bytes.is_empty());
    assert_eq!(rs.snapshot().breaker_state, 0, "breaker must close");
}

#[test]
fn hedged_and_deadlined_chaos_run_stays_byte_identical() {
    let mut clean = RigSpec::quick("s3", 0.02);
    clean.items = 32;
    clean.batch_size = 8;
    clean.fetch_impl = FetchImpl::Threaded;
    clean.shard_size = 4;
    clean.prefetch_depth = 4;
    clean.io_depth = 32;
    let mut chaos = clean.clone();
    chaos.fault_profile = "flaky";
    chaos.retry_max = 4;
    chaos.request_deadline_ms = 30_000;
    chaos.hedge_after = 1.0;
    let a = rig::build(&clean).unwrap();
    let b = rig::build(&chaos).unwrap();
    let want = collect_epochs(&a, 2);
    let got = collect_epochs(&b, 2);
    assert_eq!(got.len(), want.len(), "chaos rig lost batches");
    for (i, (w, g)) in want.iter().zip(&got).enumerate() {
        assert_eq!(w.0, g.0, "batch {i} bytes differ under hedged chaos");
        assert_eq!(w.1, g.1, "batch {i} labels differ under hedged chaos");
    }
    let s = b.resilient.as_ref().unwrap().snapshot();
    assert_eq!(s.exhausted, 0, "{s:?}");
    assert_eq!(s.deadline_hits, 0, "a 30 s deadline never fires: {s:?}");
    // hedges only fire once the p95 estimator arms (64 samples); this
    // rig is too small to promise that, so assert accounting sanity
    // rather than a count: wins are a subset of hedged ops
    assert!(s.hedge_wins <= s.hedges, "{s:?}");
}
