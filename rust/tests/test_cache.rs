//! Property, stress, and complexity tests for the unified eviction core
//! (`cdl::storage::evict::EvictCore`) and the caches built on it.
//!
//! * **Reference-model properties** — every policy (LRU, 2Q, S3-FIFO)
//!   is replayed op-for-op against a naive `VecDeque`-based model with
//!   the same semantics; after every operation the core must match the
//!   model's queue orders, byte totals, ghost list, and counters, pass
//!   its own `audit()`, and never exceed capacity.
//! * **Concurrency stress** — many threads hammer a `PrefetchStore`
//!   stacked on a `VarnishCache` (gets, puts, epoch-hint churn); both
//!   layers must come out with exact byte/link accounting and no
//!   deadlock, inside a small wall-time budget.
//! * **Eviction complexity** — per-insert cost under full-capacity churn
//!   must not grow with the resident entry count (the old hot tier paid
//!   an O(n) victim scan per eviction; the core pays O(1)).

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use cdl::prefetch::{PrefetchConfig, PrefetchStore};
use cdl::storage::{
    Bytes, CachePolicy, EvictCore, MemStore, ObjectStore, VarnishCache,
};
use cdl::util::prop::check;
use cdl::util::rng::Rng;

// ---------------------------------------------------------------------
// Naive reference model: same policy semantics as EvictCore, O(n) ops.
// ---------------------------------------------------------------------

/// (key, payload bytes, S3-FIFO frequency)
type RefEntry = (String, u64, u8);

struct RefModel {
    policy: CachePolicy,
    capacity: u64,
    ghost_cap: usize,
    /// front = most recently linked, back = eviction end
    prob: VecDeque<RefEntry>,
    main: VecDeque<RefEntry>,
    ghost: VecDeque<String>,
    hits: u64,
    misses: u64,
    insertions: u64,
    evictions: u64,
    ghost_promotions: u64,
}

impl RefModel {
    fn new(policy: CachePolicy, capacity: u64, ghost_cap: usize) -> RefModel {
        RefModel {
            policy,
            capacity,
            ghost_cap,
            prob: VecDeque::new(),
            main: VecDeque::new(),
            ghost: VecDeque::new(),
            hits: 0,
            misses: 0,
            insertions: 0,
            evictions: 0,
            ghost_promotions: 0,
        }
    }

    fn bytes(&self) -> u64 {
        self.prob.iter().map(|e| e.1).sum::<u64>()
            + self.main.iter().map(|e| e.1).sum::<u64>()
    }

    fn pos(q: &VecDeque<RefEntry>, key: &str) -> Option<usize> {
        q.iter().position(|e| e.0 == key)
    }

    /// Recency refresh mirroring `EvictCore::touch`.
    fn touch_at(&mut self, in_prob: bool, i: usize) {
        match self.policy {
            CachePolicy::Lru | CachePolicy::TwoQ => {
                if in_prob {
                    let e = self.prob.remove(i).unwrap();
                    self.prob.push_front(e);
                } else {
                    let e = self.main.remove(i).unwrap();
                    self.main.push_front(e);
                }
            }
            CachePolicy::S3Fifo => {
                let e = if in_prob { &mut self.prob[i] } else { &mut self.main[i] };
                e.2 = (e.2 + 1).min(3);
            }
        }
    }

    /// Counted lookup; returns the resident payload size on a hit.
    fn get(&mut self, key: &str) -> Option<u64> {
        if let Some(i) = Self::pos(&self.prob, key) {
            self.hits += 1;
            let sz = self.prob[i].1;
            self.touch_at(true, i);
            return Some(sz);
        }
        if let Some(i) = Self::pos(&self.main, key) {
            self.hits += 1;
            let sz = self.main[i].1;
            self.touch_at(false, i);
            return Some(sz);
        }
        self.misses += 1;
        None
    }

    fn insert(&mut self, key: &str, size: u64) {
        if size > self.capacity {
            return;
        }
        if let Some(i) = Self::pos(&self.prob, key) {
            self.prob[i].1 = size;
            self.touch_at(true, i);
            self.evict_to_fit();
            return;
        }
        if let Some(i) = Self::pos(&self.main, key) {
            self.main[i].1 = size;
            self.touch_at(false, i);
            self.evict_to_fit();
            return;
        }
        if let Some(i) = self.ghost.iter().position(|k| k == key) {
            self.ghost.remove(i);
            self.ghost_promotions += 1;
            self.insertions += 1;
            self.main.push_front((key.to_string(), size, 0));
            self.evict_to_fit();
            return;
        }
        self.insertions += 1;
        let entry = (key.to_string(), size, 0);
        match self.policy {
            CachePolicy::Lru => self.main.push_front(entry),
            CachePolicy::TwoQ | CachePolicy::S3Fifo => self.prob.push_front(entry),
        }
        self.evict_to_fit();
    }

    fn evict_to_fit(&mut self) {
        while self.bytes() > self.capacity {
            if !self.evict_one() {
                break;
            }
        }
        while self.ghost.len() > self.ghost_cap {
            self.ghost.pop_back();
        }
    }

    fn evict_one(&mut self) -> bool {
        match self.policy {
            CachePolicy::Lru => {
                if self.main.pop_back().is_none() {
                    return false;
                }
                self.evictions += 1;
                true
            }
            CachePolicy::TwoQ => {
                if let Some(e) = self.prob.pop_back() {
                    self.evictions += 1;
                    self.ghost.push_front(e.0);
                    return true;
                }
                if self.main.pop_back().is_some() {
                    self.evictions += 1;
                    return true;
                }
                false
            }
            CachePolicy::S3Fifo => loop {
                let prob_bytes: u64 = self.prob.iter().map(|e| e.1).sum();
                let use_small = !self.prob.is_empty()
                    && (prob_bytes * 10 >= self.capacity || self.main.is_empty());
                if use_small {
                    let mut e = self.prob.pop_back().unwrap();
                    if e.2 > 0 {
                        e.2 = 0;
                        self.main.push_front(e);
                        continue;
                    }
                    self.evictions += 1;
                    self.ghost.push_front(e.0);
                    return true;
                }
                let Some(mut e) = self.main.pop_back() else { return false };
                if e.2 > 0 {
                    e.2 -= 1;
                    self.main.push_front(e);
                    continue;
                }
                self.evictions += 1;
                return true;
            },
        }
    }
}

fn queue_keys(q: &VecDeque<RefEntry>) -> Vec<String> {
    q.iter().map(|e| e.0.clone()).collect()
}

/// Full structural comparison core vs model, plus the core's own audit.
fn compare(core: &EvictCore, model: &RefModel, ctx: &str) -> Result<(), String> {
    let (cp, mp) = (core.probation_keys(), queue_keys(&model.prob));
    if cp != mp {
        return Err(format!("{ctx}: probation core={cp:?} model={mp:?}"));
    }
    let (cm, mm) = (core.main_keys(), queue_keys(&model.main));
    if cm != mm {
        return Err(format!("{ctx}: main core={cm:?} model={mm:?}"));
    }
    let cg = core.ghost_keys();
    let mg: Vec<String> = model.ghost.iter().cloned().collect();
    if cg != mg {
        return Err(format!("{ctx}: ghost core={cg:?} model={mg:?}"));
    }
    if core.bytes() != model.bytes() {
        return Err(format!(
            "{ctx}: bytes core={} model={}",
            core.bytes(),
            model.bytes()
        ));
    }
    let s = core.stats();
    let counters = [
        ("hits", s.hits, model.hits),
        ("misses", s.misses, model.misses),
        ("insertions", s.insertions, model.insertions),
        ("evictions", s.evictions, model.evictions),
        ("ghost_promotions", s.ghost_promotions, model.ghost_promotions),
    ];
    for (name, got, want) in counters {
        if got != want {
            return Err(format!("{ctx}: {name} core={got} model={want}"));
        }
    }
    core.audit().map_err(|e| format!("{ctx}: audit: {e}"))
}

/// One generated scenario: a capacity, a ghost cap, and an op tape
/// ((kind, key index, size) — kind < 45 ⇒ insert, else get).
#[derive(Debug, Clone)]
struct Case {
    capacity: u64,
    ghost_cap: usize,
    ops: Vec<(usize, usize, usize)>,
}

fn run_case(policy: CachePolicy, case: &Case) -> Result<(), String> {
    let mut core =
        EvictCore::new(policy, case.capacity).with_ghost_capacity(case.ghost_cap);
    let mut model = RefModel::new(policy, case.capacity, case.ghost_cap);
    let mut gets = 0u64;
    for (step, &(kind, key_i, size)) in case.ops.iter().enumerate() {
        let key = format!("k{key_i}");
        let ctx = format!("{policy:?} step {step}");
        if kind < 45 {
            core.insert(&key, Bytes::new(vec![key_i as u8; size]));
            model.insert(&key, size as u64);
        } else {
            gets += 1;
            let got = core.get(&key).map(|d| d.len() as u64);
            let want = model.get(&key);
            if got != want {
                return Err(format!(
                    "{ctx}: get({key}) core={got:?} model={want:?}"
                ));
            }
        }
        compare(&core, &model, &ctx)?;
        if core.bytes() > case.capacity {
            return Err(format!("{ctx}: {} bytes over cap", core.bytes()));
        }
        if core.stats().ghost_entries > case.ghost_cap as u64 {
            return Err(format!("{ctx}: ghost list over its bound"));
        }
    }
    let s = core.stats();
    if s.hits + s.misses != gets {
        return Err(format!(
            "{policy:?}: hits {} + misses {} != counted lookups {gets}",
            s.hits, s.misses
        ));
    }
    Ok(())
}

fn gen_case(rng: &mut Rng) -> Case {
    Case {
        capacity: rng.range(50, 600) as u64,
        ghost_cap: rng.below(6),
        ops: {
            let n = rng.below(160);
            (0..n)
                .map(|_| (rng.below(100), rng.below(12), rng.below(700)))
                .collect()
        },
    }
}

#[test]
fn prop_lru_matches_reference_model() {
    check(
        "EvictCore[lru] == naive model after every op",
        gen_case,
        |case| run_case(CachePolicy::Lru, case),
    );
}

#[test]
fn prop_twoq_matches_reference_model() {
    check(
        "EvictCore[2q] == naive model after every op",
        gen_case,
        |case| run_case(CachePolicy::TwoQ, case),
    );
}

#[test]
fn prop_s3fifo_matches_reference_model() {
    check(
        "EvictCore[s3fifo] == naive model after every op",
        gen_case,
        |case| run_case(CachePolicy::S3Fifo, case),
    );
}

// ---------------------------------------------------------------------
// Concurrency stress: PrefetchStore over VarnishCache over MemStore.
// ---------------------------------------------------------------------

#[test]
fn stress_concurrent_prefetch_and_varnish_keep_accounting() {
    const KEYS: usize = 64;
    const THREADS: u64 = 6;
    const OPS: usize = 1200;
    const CACHE_CAP: u64 = 24_000;
    const HOT_CAP: u64 = 16_000;

    let mem = Arc::new(MemStore::new("backing"));
    for i in 0..KEYS {
        mem.put(&format!("k{i:02}"), vec![i as u8; 900 + (i * 37) % 800])
            .unwrap();
    }
    let varnish = VarnishCache::with_policy(mem, CACHE_CAP, CachePolicy::TwoQ);
    let prefetch = PrefetchStore::new(
        varnish.clone(),
        PrefetchConfig {
            depth: 16,
            hot_bytes: HOT_CAP,
            policy: CachePolicy::S3Fifo,
            ..Default::default()
        },
    );

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for t in 0..THREADS {
        let p = prefetch.clone();
        let v = varnish.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0xCAFE + t);
            for _ in 0..OPS {
                let key = format!("k{:02}", rng.below(KEYS));
                match rng.below(10) {
                    // overwrite the backing object (changes its size)
                    0 => {
                        let blob = vec![t as u8; 800 + rng.below(600)];
                        p.put(&key, blob).unwrap();
                    }
                    // hit the warm cache directly
                    1..=4 => {
                        v.get(&key).unwrap();
                    }
                    // full stack: hot tier, in-flight waits, demand path
                    _ => {
                        p.get(&key).unwrap();
                    }
                }
            }
        }));
    }
    // epoch-hint churn from the driver thread: resteers the scheduler
    // while the workers are mid-lookup
    let mut rng = Rng::new(7);
    for epoch in 0..4 {
        let order: Vec<String> = rng
            .permutation(KEYS)
            .into_iter()
            .map(|i| format!("k{i:02}"))
            .collect();
        prefetch.hint_order(epoch, &order);
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    for h in handles {
        h.join().expect("worker panicked");
    }

    // no lost byte accounting on either layer, and both inside capacity
    varnish.audit().expect("varnish accounting broken");
    prefetch.audit().expect("hot tier accounting broken");
    assert!(varnish.cached_bytes() <= CACHE_CAP);
    let report = prefetch.report();
    assert!(report.hot.bytes <= HOT_CAP);
    let c = report.engine;
    assert_eq!(
        c.hot_hits + c.inflight_hits + c.demand_misses,
        c.gets,
        "engine lookup counters inconsistent: {c:?}"
    );
    // deadlock guard: the whole stress (incl. scheduler churn) must
    // finish promptly even on a loaded runner
    assert!(
        t0.elapsed().as_secs() < 60,
        "stress took {:?} — scheduler likely wedged",
        t0.elapsed()
    );
}

// ---------------------------------------------------------------------
// Eviction complexity: O(1) in the resident entry count.
// ---------------------------------------------------------------------

/// Best-of-3 per-insert nanoseconds under full-capacity churn (every
/// insert evicts), at a given resident entry count.
fn churn_nanos_per_op(policy: CachePolicy, resident: usize) -> f64 {
    const ENTRY: usize = 64;
    const CHURN: usize = 3000;
    let mut best = f64::INFINITY;
    for round in 0..3 {
        let mut core = EvictCore::new(policy, (resident * ENTRY) as u64);
        for i in 0..resident {
            core.insert(&format!("warm{round}-{i}"), Bytes::new(vec![0u8; ENTRY]));
        }
        assert_eq!(core.len(), resident);
        let t0 = Instant::now();
        for i in 0..CHURN {
            core.insert(&format!("churn{round}-{i}"), Bytes::new(vec![1u8; ENTRY]));
        }
        assert_eq!(core.len(), resident, "churn must evict one per insert");
        best = best.min(t0.elapsed().as_nanos() as f64 / CHURN as f64);
    }
    best
}

/// The acceptance check for the refactor: with 32× more resident
/// entries, eviction-heavy inserts must not get meaningfully slower.
/// The deleted `min_by_key` scan scaled linearly (≈32× here); the
/// intrusive list is O(1), so a generous 10× noise margin separates
/// the two regimes cleanly.
#[test]
fn eviction_cost_does_not_grow_with_resident_count() {
    for policy in CachePolicy::ALL {
        let small = churn_nanos_per_op(policy, 512);
        let big = churn_nanos_per_op(policy, 16 * 1024);
        assert!(
            big < small * 10.0 + 2_000.0,
            "{policy:?}: per-eviction cost grew with resident count \
             ({small:.0} ns @512 → {big:.0} ns @16384)"
        );
    }
}
