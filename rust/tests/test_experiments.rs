//! Experiment-shape tests: run heavily-scaled-down versions of the
//! paper's key experiments and assert the *qualitative* result the paper
//! reports (who wins, roughly by how much) — the reproduction's
//! acceptance criteria (DESIGN.md §3).

use cdl::bench::rig::{self, RigSpec};
use cdl::bench::Scale;
use cdl::dataloader::FetchImpl;
use cdl::dataset::pool::run_pool;
use cdl::gil::Runtime;
use cdl::trainer::TrainerKind;

fn tiny() -> Scale {
    Scale { latency: 0.04, items: 0.3, epochs: 1.0 }
}

fn spec(storage: &'static str) -> RigSpec {
    let s = tiny();
    let mut spec = RigSpec::quick(storage, s.latency);
    spec.items = s.items(192);
    spec
}

/// Table 3 shape: s3 runtime ≫ scratch runtime; GPU idles more on s3.
#[test]
fn t3_shape_s3_much_slower_and_idler() {
    let (scratch, _) = rig::run(&spec("scratch")).unwrap();
    let (s3, _) = rig::run(&spec("s3")).unwrap();
    assert!(
        s3.runtime_s > 2.0 * scratch.runtime_s,
        "s3 {:.2}s !≫ scratch {:.2}s",
        s3.runtime_s,
        scratch.runtime_s
    );
    assert!(
        s3.util.util_zero_pct > scratch.util.util_zero_pct,
        "GPU not idler on s3: {:.1}% vs {:.1}%",
        s3.util.util_zero_pct,
        scratch.util.util_zero_pct
    );
}

/// Table 3 shape: Lightning (default logging) slower than Torch.
#[test]
fn t3_shape_lightning_slower_than_torch() {
    let (torch, _) = rig::run(&spec("scratch")).unwrap();
    let (lightning, _) =
        rig::run(&spec("scratch").with_trainer(TrainerKind::Lightning)).unwrap();
    assert!(
        lightning.runtime_s > torch.runtime_s,
        "lightning {:.2}s !> torch {:.2}s",
        lightning.runtime_s,
        torch.runtime_s
    );
}

/// Fig 5 shape: threaded and asyncio both beat vanilla on s3 by a large
/// factor, and are roughly at parity with each other.
#[test]
fn f5_shape_parallel_fetchers_win_on_s3() {
    let (vanilla, _) = rig::run(&spec("s3")).unwrap();
    let (threaded, _) = rig::run(&spec("s3").with_impl(FetchImpl::Threaded)).unwrap();
    let (asyncio, _) = rig::run(&spec("s3").with_impl(FetchImpl::Asyncio)).unwrap();
    assert!(
        threaded.mbit_per_s > 2.5 * vanilla.mbit_per_s,
        "threaded {:.1} !≫ vanilla {:.1}",
        threaded.mbit_per_s,
        vanilla.mbit_per_s
    );
    assert!(
        asyncio.mbit_per_s > 2.5 * vanilla.mbit_per_s,
        "asyncio {:.1} !≫ vanilla {:.1}",
        asyncio.mbit_per_s,
        vanilla.mbit_per_s
    );
    let parity = threaded.mbit_per_s / asyncio.mbit_per_s;
    assert!(
        (0.4..2.5).contains(&parity),
        "threaded/asyncio parity broken: {parity:.2}"
    );
}

/// Fig 5 shape: gains on scratch are modest compared to s3.
#[test]
fn f5_shape_scratch_gains_are_smaller() {
    let (vanilla, _) = rig::run(&spec("scratch")).unwrap();
    let (threaded, _) =
        rig::run(&spec("scratch").with_impl(FetchImpl::Threaded)).unwrap();
    let scratch_gain = threaded.mbit_per_s / vanilla.mbit_per_s;

    let (v_s3, _) = rig::run(&spec("s3")).unwrap();
    let (t_s3, _) = rig::run(&spec("s3").with_impl(FetchImpl::Threaded)).unwrap();
    let s3_gain = t_s3.mbit_per_s / v_s3.mbit_per_s;

    assert!(
        s3_gain > scratch_gain,
        "s3 gain {s3_gain:.2} !> scratch gain {scratch_gain:.2}"
    );
}

/// Fig 12 shape: dataset-pool throughput grows then saturates on s3.
#[test]
fn f12_shape_pool_throughput_saturates() {
    let rig = rig::build(&spec("s3")).unwrap();
    let ds = rig.dataloader.dataset().clone();
    let t1 = run_pool(ds.clone(), 1, 24, Runtime::Python, 2.0, 1);
    let t8 = run_pool(ds.clone(), 8, 48, Runtime::Python, 2.0, 2);
    let t24 = run_pool(ds, 24, 48, Runtime::Python, 2.0, 3);
    assert!(
        t8.throughput_mbit_s > 2.0 * t1.throughput_mbit_s,
        "pool8 {:.1} !≫ pool1 {:.1}",
        t8.throughput_mbit_s,
        t1.throughput_mbit_s
    );
    // diminishing returns: 3× more processes < 3× more throughput
    assert!(
        t24.throughput_mbit_s < 3.0 * t8.throughput_mbit_s,
        "no saturation: pool24 {:.1} vs pool8 {:.1}",
        t24.throughput_mbit_s,
        t8.throughput_mbit_s
    );
}

/// Fig 13 headline: modified s3 loader lands within striking distance of
/// scratch (paper: 67%; we require >15% at tiny scale).
#[test]
fn f13_shape_headline_ratio() {
    let (speedup, vs_scratch) =
        cdl::bench::exp_core::headline_factor(tiny()).unwrap();
    assert!(speedup > 2.0, "headline speedup only {speedup:.2}×");
    assert!(vs_scratch > 0.15, "vs-scratch ratio only {vs_scratch:.2}");
}

/// Fig 16 shape: ceph_os is the slowest storage backend.
#[test]
fn f16_shape_ceph_os_slowest() {
    let (ceph_os, _) = rig::run(&spec("ceph_os")).unwrap();
    let (ceph_fs, _) = rig::run(&spec("ceph_fs")).unwrap();
    let (gluster, _) = rig::run(&spec("gluster_fs")).unwrap();
    assert!(ceph_os.mbit_per_s < ceph_fs.mbit_per_s);
    assert!(ceph_os.mbit_per_s < gluster.mbit_per_s);
}

/// Fig 8 shape: lazy init beats blocking init on time-to-first-batch.
#[test]
fn f8_shape_lazy_init_wins() {
    use cdl::data::synth::{generate_corpus, CorpusSpec};
    use cdl::data::AugmentConfig;
    use cdl::dataloader::{Dataloader, DataloaderConfig};
    use cdl::dataset::{Dataset, ImageFolderDataset};
    use cdl::storage::{MemStore, ObjectStore};
    use cdl::telemetry::Recorder;
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    let mem: Arc<dyn ObjectStore> = Arc::new(MemStore::new("m"));
    generate_corpus(&mem, &CorpusSpec::tiny(16)).unwrap();
    let ds: Arc<dyn Dataset> = Arc::new(ImageFolderDataset::new(
        mem,
        AugmentConfig { crop: 16, ..Default::default() },
    ));
    let first_batch_time = |lazy: bool| {
        let dl = Dataloader::new(
            ds.clone(),
            DataloaderConfig {
                batch_size: 2,
                num_workers: 6,
                lazy_init: lazy,
                spawn_cost_override: Some(Duration::from_millis(50)),
                ..Default::default()
            },
            Recorder::new(),
        );
        let t0 = Instant::now();
        let mut it = dl.epoch(0);
        let _ = it.next().unwrap();
        let dt = t0.elapsed();
        drop(it);
        dt
    };
    let blocking = first_batch_time(false);
    let lazy = first_batch_time(true);
    assert!(
        lazy < blocking,
        "lazy {lazy:?} !< blocking {blocking:?} (6 workers × 50ms spawn)"
    );
}
