//! Telemetry-plane integration: the lock-free span ring under real
//! multi-writer contention (wraparound, no torn spans), the Chrome
//! trace export of a pipelined multi-epoch run (consumer / planner /
//! worker tracks crossing an epoch seam), and the MetricsHub JSON
//! round-trip through the crate's own parser.

use std::sync::Arc;
use std::time::Duration;

use cdl::data::synth::{generate_corpus, CorpusSpec};
use cdl::data::AugmentConfig;
use cdl::dataloader::{Dataloader, DataloaderConfig, FetchImpl};
use cdl::dataset::{Dataset, ImageFolderDataset};
use cdl::storage::{MemStore, ObjectStore};
use cdl::telemetry::{chrome, names, Recorder};
use cdl::util::json;

#[test]
fn concurrent_recording_never_tears_spans() {
    // 8 writer threads lap a deliberately tiny ring hundreds of times;
    // the seqlock stamps may *drop* spans under contention but every
    // retained span must still be internally consistent — all seven
    // fields from one write, never a mix of two
    const WRITERS: u32 = 8;
    const PER_WRITER: i64 = 5_000;
    let rec = Recorder::with_capacity(1024);
    let threads: Vec<_> = (0..WRITERS)
        .map(|w| {
            let rec = rec.clone();
            std::thread::spawn(move || {
                for i in 0..PER_WRITER {
                    // batch encodes (writer, i); every other field is
                    // derivable from it, so torn writes are detectable
                    let batch = w as i64 * 1_000_000 + i;
                    let t0 = w as f64 * 16.0 + i as f64;
                    rec.record_tagged(
                        names::GET_ITEM,
                        w,
                        batch,
                        w as i64,
                        i,
                        t0,
                        t0 + 0.5,
                    );
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    let spans = rec.snapshot();
    assert!(!spans.is_empty());
    assert!(spans.len() <= rec.capacity());
    let written = u64::from(WRITERS) * PER_WRITER as u64;
    assert!(rec.dropped() < written, "every span was dropped");
    for s in &spans {
        assert_eq!(s.name, names::GET_ITEM);
        let w = s.batch / 1_000_000;
        let i = s.batch % 1_000_000;
        assert_eq!(i64::from(s.worker), w, "torn span: {s:?}");
        assert_eq!(s.epoch, w, "torn span: {s:?}");
        assert_eq!(s.seq, i, "torn span: {s:?}");
        assert_eq!(s.t0, w as f64 * 16.0 + i as f64, "torn span: {s:?}");
        assert_eq!(s.t1 - s.t0, 0.5, "torn span: {s:?}");
    }
    // the snapshot contract: sorted by start time
    for pair in spans.windows(2) {
        assert!(pair[0].t0 <= pair[1].t0);
    }
}

fn dataset(items: usize) -> Arc<dyn Dataset> {
    let mem: Arc<dyn ObjectStore> = Arc::new(MemStore::new("m"));
    generate_corpus(&mem, &CorpusSpec::tiny(items)).unwrap();
    Arc::new(ImageFolderDataset::new(
        mem,
        AugmentConfig { crop: 16, ..Default::default() },
    ))
}

#[test]
fn pipelined_run_exports_a_chrome_trace_spanning_the_seam() {
    // the ISSUE's acceptance rig: epoch_pipeline=1, two epochs, then a
    // Chrome trace with consumer/planner/worker tracks and the epoch
    // seams as instant events — and it must parse as JSON
    let rec = Recorder::new();
    let dl = Dataloader::new(
        dataset(24),
        DataloaderConfig {
            batch_size: 8,
            num_workers: 3,
            fetch_impl: FetchImpl::Threaded,
            num_fetch_workers: 4,
            arena_slabs: 12,
            work_stealing: true,
            steal_items: true,
            consumer_credit: 3,
            epoch_pipeline: 1,
            spawn_cost_override: Some(Duration::ZERO),
            ..Default::default()
        },
        rec.clone(),
    );
    for epoch in 0..2 {
        for b in dl.epoch(epoch) {
            b.recycle();
        }
    }
    let spans = rec.snapshot();
    // the consumer lane is (epoch, seq)-tagged end to end
    assert!(
        spans
            .iter()
            .any(|s| s.name == names::GET_BATCH && s.epoch == 1 && s.seq >= 0),
        "no epoch-1 tagged get_batch span"
    );
    assert!(
        spans.iter().filter(|s| s.name == names::EPOCH_SEAM).count() >= 2,
        "missing epoch-seam markers"
    );
    assert!(
        spans.iter().any(|s| s.name == names::PLAN_PUBLISH),
        "planner published no plan spans"
    );

    let doc = chrome::chrome_trace(&spans);
    let parsed = json::parse(&doc.to_string()).unwrap();
    let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
    let labels: Vec<&str> = events
        .iter()
        .filter(|e| e.get("name").and_then(|n| n.as_str()) == Some("thread_name"))
        .filter_map(|e| e.at(&["args", "name"]).and_then(|n| n.as_str()))
        .collect();
    assert!(labels.contains(&"consumer"), "{labels:?}");
    assert!(labels.contains(&"planner"), "{labels:?}");
    assert!(labels.iter().any(|l| l.starts_with("worker ")), "{labels:?}");
    let seams = events
        .iter()
        .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("i"))
        .count();
    assert!(seams >= 2, "expected ≥2 epoch-seam instants, got {seams}");
    let has_get_batch = events.iter().any(|e| {
        e.get("ph").and_then(|p| p.as_str()) == Some("X")
            && e.get("name").and_then(|n| n.as_str()) == Some("get_batch")
    });
    assert!(has_get_batch, "no get_batch duration events in the trace");
}

#[test]
fn metrics_hub_snapshot_round_trips_through_json() {
    let rec = Recorder::new();
    let hub = rec.metrics();
    hub.metric("loader.item_steals").add(7);
    hub.set("reorder.high_water", 5);
    hub.metric("gate.credit_blocked_ns").add_duration(Duration::from_millis(3));
    let parsed = json::parse(&hub.snapshot().to_string()).unwrap();
    assert_eq!(
        parsed.at(&["loader.item_steals"]).and_then(|j| j.as_usize()),
        Some(7)
    );
    assert_eq!(
        parsed.at(&["reorder.high_water"]).and_then(|j| j.as_usize()),
        Some(5)
    );
    assert_eq!(
        parsed.at(&["gate.credit_blocked_ns"]).and_then(|j| j.as_usize()),
        Some(3_000_000)
    );
}
