//! Byte-identical equivalence of batched ring submission against the
//! per-item read paths: the same rig with `io_depth` on vs off must
//! deliver identical batches (ids, bytes, labels, indices, raw counts)
//! for every fused fetcher × dispatch mode, across a pipelined epoch
//! seam, and through the shard facade — plus sanity on the ring
//! counters (everything submitted completes, nothing errors, and the
//! in-flight high-water mark actually exceeds one).

use cdl::bench::rig::{self, RigSpec};
use cdl::dataloader::FetchImpl;

const IO_DEPTH: usize = 64;

/// One delivered batch, copied out before its slab is recycled.
type Snap = (usize, Vec<u8>, Vec<i32>, Vec<usize>, u64);

fn drain(r: &rig::Rig, epochs: usize) -> Vec<Snap> {
    let mut out = Vec::new();
    for epoch in 0..epochs {
        for b in r.dataloader.epoch(epoch) {
            out.push((
                b.id,
                b.images.data.clone(),
                b.labels.clone(),
                b.indices.clone(),
                b.raw_bytes,
            ));
            b.recycle();
        }
    }
    out
}

fn base_spec(fetch: FetchImpl) -> RigSpec {
    let mut spec = RigSpec::quick("s3", 0.02);
    spec.items = 37; // partial tail batch
    spec.batch_size = 8;
    spec.num_workers = 3;
    spec.fetch_impl = fetch;
    spec.num_fetch_workers = 4;
    spec.arena_slabs = 16;
    spec.runtime = cdl::gil::Runtime::Native;
    spec
}

fn assert_identical(legacy: &[Snap], ring: &[Snap], ctx: &str) {
    assert!(!legacy.is_empty(), "{ctx}: legacy rig delivered nothing");
    assert_eq!(legacy.len(), ring.len(), "{ctx}: batch count");
    for (a, b) in legacy.iter().zip(ring.iter()) {
        assert_eq!(a.0, b.0, "{ctx}: batch id");
        assert_eq!(a.1, b.1, "{ctx}: batch {} bytes", a.0);
        assert_eq!(a.2, b.2, "{ctx}: batch {} labels", a.0);
        assert_eq!(a.3, b.3, "{ctx}: batch {} indices", a.0);
        assert_eq!(a.4, b.4, "{ctx}: batch {} raw bytes", a.0);
    }
}

/// Run one spec with the ring off and on; the delivered stream must be
/// identical and the ring must have actually carried the reads.
fn check_equivalence(mut spec: RigSpec, epochs: usize, ctx: &str) {
    spec.io_depth = 0;
    let legacy = rig::build(&spec).unwrap();
    let want = drain(&legacy, epochs);
    drop(legacy);

    spec.io_depth = IO_DEPTH;
    let ringed = rig::build(&spec).unwrap();
    let got = drain(&ringed, epochs);
    assert_identical(&want, &got, ctx);

    let ring = ringed.ring.as_ref().unwrap_or_else(|| {
        panic!("{ctx}: io_depth={IO_DEPTH} built no ring")
    });
    let s = ring.stats();
    assert!(s.submitted > 0, "{ctx}: ring never used");
    assert_eq!(s.submitted, s.completed, "{ctx}: ops lost in flight");
    assert_eq!(s.errors, 0, "{ctx}: ring errors");
    assert_eq!(s.inflight, 0, "{ctx}: in-flight after drain");
    assert!(
        s.inflight_hwm > 1,
        "{ctx}: reads never overlapped (hwm {})",
        s.inflight_hwm
    );
}

/// Every fused fetcher × dispatch mode delivers the same bytes with
/// batched submission as with per-item reads.
#[test]
fn ring_matches_per_item_across_fetchers_and_dispatch() {
    for fetch in [FetchImpl::Threaded, FetchImpl::Asyncio] {
        for (stealing, items) in [(false, false), (true, false), (true, true)] {
            let mut spec = base_spec(fetch);
            spec.work_stealing = stealing;
            spec.steal_items = items;
            check_equivalence(
                spec,
                1,
                &format!("{fetch:?}/stealing={stealing}/items={items}"),
            );
        }
    }
}

/// The ring rides through a pipelined epoch seam (persistent workers,
/// pre-published next-epoch plan, credit-bounded reorder buffer)
/// without reordering or corrupting either epoch.
#[test]
fn ring_matches_per_item_across_pipelined_epoch_seam() {
    let mut spec = base_spec(FetchImpl::Threaded);
    spec.work_stealing = true;
    spec.steal_items = true;
    spec.epoch_pipeline = 1;
    spec.consumer_credit = 6;
    check_equivalence(spec, 2, "pipelined-seam");
}

/// In shard mode the ring hangs below the shard facade (window fetches
/// become ring ops); delivered batches still match the ring-off rig.
#[test]
fn ring_matches_per_item_under_shard_windows() {
    let mut spec = base_spec(FetchImpl::Threaded);
    spec.work_stealing = true;
    spec.shard_size = 6;
    spec.prefetch_depth = 8;
    spec.epoch_pipeline = 1;
    check_equivalence(spec, 2, "shard-windows");
}
