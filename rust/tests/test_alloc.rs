//! Steady-state allocation regression for the fused hot path: once the
//! arena pool, the CRC tables, and the augment scratch are warm, one
//! full checkout → parse → augment-into → finish → recycle cycle
//! performs **zero** heap allocations — and the `get_into` read path
//! over a real-file `DirStore` holds the same bar end to end (pread
//! into a reused scratch, decode straight into the slot). The batched
//! submission ring holds a related bar: the submitting thread's wave
//! cost is constant, independent of how many reads the wave carries.
//! The resilience layer holds it too: mounted fault-free over the same
//! DirStore, its breaker check + latency sample add zero allocations.
//!
//! The assertions read the *per-thread* counters of the crate's
//! counting global allocator, so each test measures only its own
//! thread and stays immune to the parallel test harness.

use std::sync::Arc;
use std::time::Duration;

use cdl::data::augment::{Augment, AugmentConfig};
use cdl::data::simg::SimgRef;
use cdl::data::synth::{generate_corpus, CorpusSpec};
use cdl::dataloader::{BatchArena, Dataloader, DataloaderConfig};
use cdl::dataset::{Dataset, ImageFolderDataset, ItemMeta};
use cdl::gil::Gil;
use cdl::storage::{Bytes, DirStore, IoRing, MemStore, ObjectStore, ReadOp};
use cdl::util::alloc;

#[test]
fn arena_assembly_is_zero_alloc_in_steady_state_across_epoch_seams() {
    const B: usize = 16;
    const CROP: usize = 24;
    // 6 batches per simulated epoch: the measured window below spans
    // three epoch boundaries, so the generation-tagged re-checkout
    // (epoch bump + claim-word reset) is proven allocation-free too —
    // persistent workers re-cross seams with the same slabs forever
    const PER_EPOCH: usize = 6;
    let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new("m"));
    let (keys, _) = generate_corpus(&store, &CorpusSpec::tiny(B)).unwrap();
    // raw object bytes resident (the storage layer shares Arcs, so the
    // loop below touches no storage-side allocation either)
    let raws: Vec<Bytes> = keys.iter().map(|k| store.get(k).unwrap()).collect();
    let aug = Augment::new(AugmentConfig { crop: CROP, ..Default::default() });
    let arena = BatchArena::new(CROP, B, 2);

    let run_batch = |seq: usize| {
        let (epoch, id) = (seq / PER_EPOCH, seq % PER_EPOCH);
        let builder = arena.clone().checkout_tagged(id, seq, epoch, B);
        for pos in 0..B {
            let raw = &raws[pos];
            builder
                .fill(pos, pos, |out| {
                    let img = SimgRef::parse(&raw[..])?;
                    aug.apply_u8_into(&img, epoch, pos, out);
                    Ok(ItemMeta { label: img.label, raw_bytes: raw.len() })
                })
                .unwrap();
        }
        let batch = builder.finish().unwrap();
        assert_eq!(batch.id, id);
        assert_eq!(batch.len(), B);
        assert_eq!(batch.images.data.len(), B * CROP * CROP * 3);
        batch.recycle();
    };

    // warm-up: first slab allocation, CRC tables, column-LUT scratch
    for seq in 0..3 {
        run_batch(seq);
    }

    let before = alloc::thread_counters();
    for seq in 3..19 {
        run_batch(seq); // crosses the seams at seq 6, 12, and 18
    }
    let delta = alloc::thread_counters().since(before);

    assert_eq!(
        delta.allocs, 0,
        "steady-state fused assembly allocated ({} batches): {delta:?}",
        16
    );
    assert_eq!(
        delta.frees, 0,
        "steady-state fused assembly freed ({} batches): {delta:?}",
        16
    );

    // sanity: the pool really was recycling one slab the whole time
    let stats = arena.stats();
    assert_eq!(stats.checkouts, 19, "{stats:?}");
    assert_eq!(stats.fresh, 1, "{stats:?}");
    assert_eq!(stats.reused, 18, "{stats:?}");
}

#[test]
fn steady_state_epoch_attach_skips_pipeline_setup_allocs() {
    // persistent workers: the first `epoch()` builds the whole pipeline
    // (bounded channel, planner, dispatch queues, worker bookkeeping);
    // a steady-state `epoch()` only publishes the next plan. The
    // consumer-thread allocation bill must reflect that — no per-epoch
    // channel/thread setup is tolerated.
    if alloc::counters().allocs == 0 {
        return; // counting allocator not installed (--no-default-features)
    }
    let mk = || {
        let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new("m"));
        generate_corpus(&store, &CorpusSpec::tiny(8)).unwrap();
        let ds: Arc<dyn Dataset> = Arc::new(ImageFolderDataset::new(
            store,
            AugmentConfig { crop: 16, ..Default::default() },
        ));
        Dataloader::new(
            ds,
            DataloaderConfig {
                batch_size: 4,
                num_workers: 8,
                spawn_cost_override: Some(Duration::ZERO),
                ..Default::default()
            },
            cdl::telemetry::Recorder::new(),
        )
    };

    // cold: pipeline construction + plan publication
    let cold_dl = mk();
    let before = alloc::thread_counters();
    let cold_iter = cold_dl.epoch(0);
    let cold = alloc::thread_counters().since(before).allocs;
    drop(cold_iter);

    // steady: two full epochs warm the persistent pipeline, then the
    // attach for epoch 2 is plan-only
    let dl = mk();
    for epoch in 0..2 {
        for b in dl.epoch(epoch) {
            b.recycle();
        }
    }
    let before = alloc::thread_counters();
    let steady_iter = dl.epoch(2);
    let steady = alloc::thread_counters().since(before).allocs;
    drop(steady_iter);

    // the cold attach additionally pays the channel + per-worker queue
    // + planner construction, so any steady-state attach that re-does
    // pipeline setup shows up as steady ≥ cold
    assert!(
        steady < cold,
        "steady-state epoch attach allocated {steady} (cold setup: {cold}) — \
         per-epoch pipeline setup has crept back in"
    );
}

#[test]
fn ring_submission_path_allocs_are_constant_per_wave() {
    // the batched-submission wave recycles owned (key, buf) pairs
    // through the completion queue, so the submitting thread's
    // steady-state bill per wave is a handful of queue-plumbing
    // allocations (the op vector, the completion queue, the dispatch
    // future) — *independent of how many reads the wave carries*. A
    // per-op key or buffer allocation creeping back in shows up as
    // ≥ OPS allocs per wave; the bound below is far under that.
    // (Executor-side work lands on the ring thread and is invisible to
    // this thread's counters by design — the submission path is what
    // the fetcher's hot loop pays.)
    const OPS: usize = 64;
    const WAVES: u64 = 4;
    let m = Arc::new(MemStore::new("m"));
    for i in 0..OPS {
        m.put(&format!("k{i:02}"), vec![i as u8; 4096]).unwrap();
    }
    let ring = IoRing::new(m as Arc<dyn ObjectStore>, 128);
    // the recycled pool a ring-enabled wave fetcher keeps per worker
    let mut pool: Vec<(String, Vec<u8>)> = (0..OPS)
        .map(|i| (format!("k{i:02}"), Vec::with_capacity(4096)))
        .collect();
    let run_wave = |pool: &mut Vec<(String, Vec<u8>)>| {
        let mut ops = Vec::with_capacity(OPS);
        for slot in 0..OPS {
            let (key, buf) = pool.pop().unwrap();
            ops.push(ReadOp::whole(slot, key, buf));
        }
        let mut sub = ring.submit(ops);
        while let Some(c) = sub.next() {
            assert_eq!(c.result.unwrap(), 4096);
            pool.push((c.key, c.buf));
        }
    };

    // warm-up: executor spawn, buffer growth to object size
    for _ in 0..3 {
        run_wave(&mut pool);
    }

    let before = alloc::thread_counters();
    for _ in 0..WAVES {
        run_wave(&mut pool);
    }
    let delta = alloc::thread_counters().since(before);
    let per_wave = delta.allocs / WAVES;
    assert!(
        per_wave < 16,
        "ring submission path allocates per op again: {per_wave} \
         allocs/wave for {OPS}-read waves ({delta:?})"
    );
}

#[test]
fn span_recording_and_metric_updates_are_zero_alloc() {
    // the telemetry plane must be cheap enough to leave on inside the
    // zero-alloc steady state: one record_tagged is a ticket fetch_add,
    // a claim CAS and a fixed-size volatile write into a preallocated
    // ring; updating a cached Metric handle is one relaxed fetch_add —
    // no Mutex, no heap traffic, through full ring wraparound
    let rec = cdl::telemetry::Recorder::with_capacity(1024);
    let steals = rec.metrics().metric("loader.item_steals");
    // warm-up: TLS shard hint + first lap of the ring
    for i in 0..2048i64 {
        rec.record_tagged(cdl::telemetry::names::GET_ITEM, 1, i, 0, i, 0.0, 0.5);
    }
    let before = alloc::thread_counters();
    for i in 0..4096i64 {
        let t0 = rec.now();
        rec.record_tagged(cdl::telemetry::names::GET_ITEM, 1, i, 1, i, t0, t0 + 0.001);
        steals.add(1);
    }
    let delta = alloc::thread_counters().since(before);
    assert_eq!(delta.allocs, 0, "steady-state span recording allocated: {delta:?}");
    assert_eq!(delta.frees, 0, "steady-state span recording freed: {delta:?}");
    assert_eq!(steals.get(), 4096);
    assert!(rec.len() <= rec.capacity());
}

#[test]
fn governor_step_and_seam_commit_are_zero_alloc_in_steady_state() {
    // the Governor rides inside the zero-alloc steady state: one
    // end_epoch is stall attribution over a Copy Signals struct, a
    // push into the preallocated decision ring, pre-registered metric
    // handle updates and one lock-free span; the seam commit is six
    // atomic swaps plus the (empty here) applier list
    use cdl::governor::{Governor, GovernorConfig, KnobBounds, Signals, TunedKnobs};
    let cfg = DataloaderConfig {
        num_workers: 4,
        arena_slabs: 16,
        work_stealing: true,
        consumer_credit: 4,
        prefetch_depth: 8,
        io_depth: 8,
        ..Default::default()
    };
    let knobs = TunedKnobs::from_config(&cfg);
    let bounds = KnobBounds::derive(&cfg, true, true, true);
    let mut gov = Governor::new(GovernorConfig::default(), knobs.clone(), bounds)
        .with_recorder(cdl::telemetry::Recorder::new());
    let sig = |epoch: usize| Signals {
        epoch,
        batches: 100,
        // alternating objective: keeps AND reverts both exercised
        epoch_s: if epoch % 2 == 0 { 10.0 } else { 8.0 },
        credit_blocked_s: 0.4,
        prefetch_hit_ratio: 0.5,
        ring_queued: 1,
        ..Default::default()
    };
    // warm-up: baseline formation, first probes
    for epoch in 0..4 {
        gov.end_epoch(&sig(epoch));
        knobs.commit();
    }
    let before = alloc::thread_counters();
    for epoch in 4..36 {
        gov.end_epoch(&sig(epoch));
        knobs.commit();
    }
    let delta = alloc::thread_counters().since(before);
    assert_eq!(
        delta.allocs, 0,
        "steady-state Governor step/commit allocated: {delta:?}"
    );
    assert_eq!(
        delta.frees, 0,
        "steady-state Governor step/commit freed: {delta:?}"
    );
    let (probes, _, _) = gov.counts();
    assert!(probes > 4, "the measured window really probed ({probes})");
}

#[cfg(unix)]
#[test]
fn dirstore_fd_cache_holds_zero_alloc_reads_past_the_handle_cap() {
    // regression for the wholesale-clear bug: with a working set larger
    // than the handle cap, the old cache cleared *everything* at the
    // cap, so even the hottest keys re-opened (and re-allocated) every
    // cycle. Under LRU eviction the hot subset stays resident — its
    // reads stay allocation-free — while only the cold tail churns, one
    // victim at a time.
    const CAP: usize = 8;
    const HOT: usize = 6; // < CAP: must never be evicted
    const COLD: usize = 6; // HOT + COLD > CAP: the cache is over-subscribed
    let root = std::env::temp_dir().join(format!(
        "cdl-alloc-fdcache-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&root);
    let store = DirStore::with_handle_cap(&root, CAP).unwrap();
    // pre-build the key strings so the measured loop touches no format!
    let keys: Vec<String> = (0..HOT + COLD).map(|i| format!("k{i:02}")).collect();
    for (i, k) in keys.iter().enumerate() {
        store.put(k, vec![i as u8; 256]).unwrap();
    }
    let mut buf = vec![0u8; 512];

    // warm-up: populate handles, settle the LRU order
    for cycle in 0..3 {
        for k in &keys[..HOT] {
            store.get_into(k, &mut buf).unwrap();
        }
        store.get_into(&keys[HOT + cycle % COLD], &mut buf).unwrap();
    }

    let evictions_before = store.handle_evictions();
    let mut hot_allocs = 0u64;
    let mut cold_opens = 0u64;
    for cycle in 3..9 {
        // the hot subset must be pure cache hits: no opens, no allocs
        let before = alloc::thread_counters();
        for k in &keys[..HOT] {
            store.get_into(k, &mut buf).unwrap();
        }
        hot_allocs += alloc::thread_counters().since(before).allocs;
        // one cold key past the cap: evicts exactly one LRU victim
        store.get_into(&keys[HOT + cycle % COLD], &mut buf).unwrap();
        cold_opens += 1;
        assert_eq!(
            store.cached_handles(),
            CAP,
            "fd cache collapsed below the cap (wholesale clear is back)"
        );
    }
    assert_eq!(
        hot_allocs, 0,
        "hot-key reads allocated with the working set over the cap"
    );
    assert_eq!(
        store.handle_evictions() - evictions_before,
        cold_opens,
        "evictions not one-per-cold-open"
    );
    let _ = std::fs::remove_dir_all(&root);
}

#[cfg(unix)]
#[test]
fn resilient_layer_fault_free_get_into_is_zero_alloc_in_steady_state() {
    // the resilience layer mounted over a fault-free DirStore must not
    // tax the blocking hot path: one breaker load, the inner pread, one
    // latency sample into the preallocated estimator ring (its periodic
    // p95 recompute sorts a stack copy) — no heap traffic at all
    use cdl::storage::{ResilienceConfig, ResilientStore};
    const N: usize = 8;
    let root = std::env::temp_dir().join(format!(
        "cdl-alloc-resilient-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&root);
    let store: Arc<dyn ObjectStore> = Arc::new(DirStore::open(&root).unwrap());
    generate_corpus(&store, &CorpusSpec::tiny(N)).unwrap();
    let keys = store.keys();
    // retries + deadline armed (the layer is really on), hedging off
    let rs = ResilientStore::new(store, ResilienceConfig::new(3, 250, 0.0), 7);
    let mut buf = vec![0u8; 1 << 20];

    // warm-up: handle cache, estimator ring, breaker fast path
    for _ in 0..2 {
        for k in &keys {
            rs.get_into(k, &mut buf).unwrap();
        }
    }

    let before = alloc::thread_counters();
    for _ in 0..8 {
        for k in &keys {
            // 64 samples: crosses the estimator's periodic p95 recompute
            rs.get_into(k, &mut buf).unwrap();
        }
    }
    let delta = alloc::thread_counters().since(before);
    assert_eq!(
        delta.allocs, 0,
        "fault-free resilient get_into allocated: {delta:?}"
    );
    assert_eq!(
        delta.frees, 0,
        "fault-free resilient get_into freed: {delta:?}"
    );
    let s = rs.snapshot();
    assert_eq!(s.retries, 0, "{s:?}");
    assert_eq!(s.exhausted, 0, "{s:?}");
    assert!(s.ops >= 80, "{s:?}");
    let _ = std::fs::remove_dir_all(&root);
}

#[cfg(unix)]
#[test]
fn dirstore_get_into_item_path_is_zero_alloc_in_steady_state() {
    // the full per-item read path over real files: cached-handle pread
    // into the thread's raw scratch, zero-copy SIMG parse, augment into
    // the slot — no Vec per read, no allocation once handles, scratch,
    // and LUTs are warm
    const N: usize = 8;
    const CROP: usize = 24;
    let root = std::env::temp_dir().join(format!(
        "cdl-alloc-getinto-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&root);
    let store: Arc<dyn ObjectStore> = Arc::new(DirStore::open(&root).unwrap());
    generate_corpus(&store, &CorpusSpec::tiny(N)).unwrap();
    let ds = ImageFolderDataset::new(
        store,
        AugmentConfig { crop: CROP, ..Default::default() },
    );
    let gil = Gil::native();
    let mut slot = vec![0u8; CROP * CROP * 3];

    // warm-up: handle cache, raw scratch growth, CRC tables, column LUT
    for _ in 0..2 {
        for index in 0..N {
            ds.get_item_into(index, &gil, &mut slot).unwrap();
        }
    }

    let before = alloc::thread_counters();
    for _ in 0..4 {
        for index in 0..N {
            ds.get_item_into(index, &gil, &mut slot).unwrap();
        }
    }
    let delta = alloc::thread_counters().since(before);
    assert_eq!(
        delta.allocs, 0,
        "steady-state get_into item path allocated: {delta:?}"
    );
    assert_eq!(
        delta.frees, 0,
        "steady-state get_into item path freed: {delta:?}"
    );
    let _ = std::fs::remove_dir_all(&root);
}
