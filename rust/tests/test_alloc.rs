//! Steady-state allocation regression for the fused hot path: once the
//! arena pool, the CRC tables, and the augment scratch are warm, one
//! full checkout → parse → augment-into → finish → recycle cycle
//! performs **zero** heap allocations.
//!
//! This file deliberately contains a single test: the assertion reads
//! the *per-thread* counters of the crate's counting global allocator,
//! and a quiet binary keeps the measured thread unambiguous.

use std::sync::Arc;

use cdl::data::augment::{Augment, AugmentConfig};
use cdl::data::simg::SimgRef;
use cdl::data::synth::{generate_corpus, CorpusSpec};
use cdl::dataloader::BatchArena;
use cdl::dataset::ItemMeta;
use cdl::storage::{Bytes, MemStore, ObjectStore};
use cdl::util::alloc;

#[test]
fn arena_assembly_is_zero_alloc_in_steady_state() {
    const B: usize = 16;
    const CROP: usize = 24;
    let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new("m"));
    let (keys, _) = generate_corpus(&store, &CorpusSpec::tiny(B)).unwrap();
    // raw object bytes resident (the storage layer shares Arcs, so the
    // loop below touches no storage-side allocation either)
    let raws: Vec<Bytes> = keys.iter().map(|k| store.get(k).unwrap()).collect();
    let aug = Augment::new(AugmentConfig { crop: CROP, ..Default::default() });
    let arena = BatchArena::new(CROP, B, 2);

    let run_batch = |id: usize| {
        let builder = arena.clone().checkout(id, B);
        for pos in 0..B {
            let raw = &raws[pos];
            builder
                .fill(pos, pos, |out| {
                    let img = SimgRef::parse(&raw[..])?;
                    aug.apply_u8_into(&img, id, pos, out);
                    Ok(ItemMeta { label: img.label, raw_bytes: raw.len() })
                })
                .unwrap();
        }
        let batch = builder.finish().unwrap();
        assert_eq!(batch.len(), B);
        assert_eq!(batch.images.data.len(), B * CROP * CROP * 3);
        batch.recycle();
    };

    // warm-up: first slab allocation, CRC tables, column-LUT scratch
    for id in 0..3 {
        run_batch(id);
    }

    let before = alloc::thread_counters();
    for id in 3..19 {
        run_batch(id);
    }
    let delta = alloc::thread_counters().since(before);

    assert_eq!(
        delta.allocs, 0,
        "steady-state fused assembly allocated ({} batches): {delta:?}",
        16
    );
    assert_eq!(
        delta.frees, 0,
        "steady-state fused assembly freed ({} batches): {delta:?}",
        16
    );

    // sanity: the pool really was recycling one slab the whole time
    let stats = arena.stats();
    assert_eq!(stats.checkouts, 19, "{stats:?}");
    assert_eq!(stats.fresh, 1, "{stats:?}");
    assert_eq!(stats.reused, 18, "{stats:?}");
}
