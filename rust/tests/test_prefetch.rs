//! Integration tests for the sampler-ahead prefetch subsystem: the full
//! dataloader pipeline over a `PrefetchStore`, in-order delivery under
//! shuffled samplers, latency hiding on simulated remotes, and hint
//! forwarding through wrapper stores.

use std::sync::Arc;
use std::time::{Duration, Instant};

use cdl::data::synth::{generate_corpus, CorpusSpec};
use cdl::data::AugmentConfig;
use cdl::dataloader::{Batch, Dataloader, DataloaderConfig, FetchImpl};
use cdl::dataset::{Dataset, ImageFolderDataset};
use cdl::prefetch::{CachePolicy, PrefetchConfig, PrefetchStore};
use cdl::storage::{
    MemStore, ObjectStore, RemoteProfile, SimRemoteStore, VarnishCache,
};
use cdl::telemetry::Recorder;

fn corpus(items: usize) -> Arc<dyn ObjectStore> {
    let m: Arc<dyn ObjectStore> = Arc::new(MemStore::new("c"));
    generate_corpus(&m, &CorpusSpec::tiny(items)).unwrap();
    m
}

fn s3_over(items: usize, latency_scale: f64) -> Arc<dyn ObjectStore> {
    SimRemoteStore::new(
        corpus(items),
        RemoteProfile::s3().scaled(latency_scale),
        9,
    )
}

fn loader_over(
    store: Arc<dyn ObjectStore>,
    imp: FetchImpl,
    workers: usize,
    batch: usize,
) -> Dataloader {
    let ds: Arc<dyn Dataset> = Arc::new(ImageFolderDataset::new(
        store,
        AugmentConfig { crop: 16, ..Default::default() },
    ));
    Dataloader::new(
        ds,
        DataloaderConfig {
            batch_size: batch,
            num_workers: workers,
            fetch_impl: imp,
            spawn_cost_override: Some(Duration::ZERO),
            ..Default::default()
        },
        Recorder::new(),
    )
}

fn check_coverage(batches: &[Batch], n_items: usize) {
    let mut seen: Vec<usize> =
        batches.iter().flat_map(|b| b.indices.iter().copied()).collect();
    seen.sort_unstable();
    assert_eq!(seen, (0..n_items).collect::<Vec<_>>());
}

/// Shuffled epochs over a prefetching store still deliver every batch,
/// in id order, exactly covering the dataset — across all fetchers.
#[test]
fn in_order_delivery_under_shuffled_sampler() {
    for imp in FetchImpl::all() {
        let store = PrefetchStore::new(
            s3_over(22, 0.03),
            PrefetchConfig { depth: 12, ..Default::default() },
        );
        let dl = loader_over(store, imp, 3, 5);
        for epoch in 0..2 {
            let batches: Vec<Batch> = dl.epoch(epoch).collect();
            assert_eq!(batches.len(), 5, "{imp:?}");
            let ids: Vec<usize> = batches.iter().map(|b| b.id).collect();
            assert_eq!(ids, vec![0, 1, 2, 3, 4], "{imp:?}");
            check_coverage(&batches, 22);
        }
    }
}

/// The engine reuses the sampler hint: after a drained epoch the hot
/// tier has been fed by background fetches, not only demand fills.
#[test]
fn engine_prefetches_during_epoch() {
    let store = PrefetchStore::new(
        s3_over(24, 0.05),
        PrefetchConfig { depth: 24, ..Default::default() },
    );
    let dl = loader_over(store.clone(), FetchImpl::Vanilla, 2, 8);
    let batches: Vec<Batch> = dl.epoch(0).collect();
    assert_eq!(batches.len(), 3);
    let c = store.counters();
    assert!(c.issued > 0, "no speculative fetches issued: {c:?}");
    assert!(
        c.hot_hits + c.inflight_hits > 0,
        "engine never hid a lookup: {c:?}"
    );
    assert_eq!(c.gets, 24, "{c:?}");
}

/// Prefetching must make a vanilla epoch on s3 meaningfully faster.
#[test]
fn prefetch_speeds_up_vanilla_epoch_on_s3() {
    let drain = |prefetch: bool| -> f64 {
        // latency scale high enough that storage time dwarfs scheduler
        // noise on loaded CI runners (plain epoch ≈ 400 ms)
        let base = s3_over(24, 0.15);
        let store: Arc<dyn ObjectStore> = if prefetch {
            PrefetchStore::new(
                base,
                PrefetchConfig { depth: 16, max_inflight: 16, ..Default::default() },
            )
        } else {
            base
        };
        let dl = loader_over(store, FetchImpl::Vanilla, 2, 8);
        let t0 = Instant::now();
        let batches: Vec<Batch> = dl.epoch(0).collect();
        assert_eq!(batches.len(), 3);
        t0.elapsed().as_secs_f64()
    };
    let off = drain(false);
    let on = drain(true);
    assert!(
        on < 0.7 * off,
        "prefetch epoch {on:.3}s not ≪ plain epoch {off:.3}s"
    );
}

/// Epoch hints flow through wrapper stores down to the engine.
#[test]
fn hint_forwards_through_varnish() {
    let prefetch = PrefetchStore::new(
        corpus(16),
        PrefetchConfig { depth: 16, ..Default::default() },
    );
    let varnish = VarnishCache::new(prefetch.clone(), 1 << 20);
    let keys = prefetch.keys();
    varnish.hint_order(0, &keys);
    let t0 = Instant::now();
    while prefetch.counters().completed < 16 {
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "hint never reached the engine: {:?}",
            prefetch.counters()
        );
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// Per-tier counters surface through the report and the summary table.
#[test]
fn tier_counters_reported() {
    let store = PrefetchStore::new(
        s3_over(16, 0.02),
        PrefetchConfig { depth: 16, policy: CachePolicy::TwoQ, ..Default::default() },
    );
    let dl = loader_over(store.clone(), FetchImpl::Vanilla, 2, 8);
    let _: Vec<Batch> = dl.epoch(0).collect();
    let r = store.report();
    assert_eq!(
        r.engine.hot_hits + r.engine.inflight_hits + r.engine.demand_misses,
        r.engine.gets,
        "{r:?}"
    );
    assert!(r.hot.bytes > 0);
    assert_eq!(r.warm_label, "s3");
    let t = store.summary_table("tiers");
    assert_eq!(t.rows.len(), 2);
}

/// Config-file knobs reach the engine through the rig.
#[test]
fn config_knobs_drive_the_rig() {
    use cdl::bench::rig::{self, RigSpec};
    use cdl::config::ExperimentConfig;

    let mut cfg = ExperimentConfig::default();
    cfg.apply_text("prefetch_depth = 24\nprefetch_policy = 2q\n").unwrap();
    let mut spec = RigSpec::quick("s3", 0.02);
    spec.items = 16;
    spec.batch_size = 8;
    spec.prefetch_depth = cfg.loader.prefetch_depth;
    spec.prefetch_policy = cfg.loader.prefetch_policy;
    let rig = rig::build(&spec).unwrap();
    let p = rig.prefetch.as_ref().expect("prefetch layer missing");
    assert_eq!(p.config().depth, 24);
    assert_eq!(p.config().policy, CachePolicy::TwoQ);
}

/// The S3-FIFO policy threads from a config file through the rig into
/// both byte-capped caches (varnish warm cache and prefetch hot tier),
/// and an epoch drains over the stack.
#[test]
fn s3fifo_policy_reaches_both_cache_layers() {
    use cdl::bench::rig::{self, RigSpec};
    use cdl::config::ExperimentConfig;

    let mut cfg = ExperimentConfig::default();
    cfg.apply_text(
        "prefetch_depth = 8\nprefetch_policy = s3fifo\n\
         cache_bytes = 262144\ncache_policy = s3fifo\n",
    )
    .unwrap();
    let mut spec = RigSpec::quick("s3", 0.02);
    spec.items = 16;
    spec.batch_size = 8;
    spec.prefetch_depth = cfg.loader.prefetch_depth;
    spec.prefetch_policy = cfg.loader.prefetch_policy;
    spec.cache_bytes = cfg.cache_bytes;
    spec.cache_policy = cfg.cache_policy;
    let rig = rig::build(&spec).unwrap();
    let p = rig.prefetch.as_ref().expect("prefetch layer missing");
    assert_eq!(p.config().policy, CachePolicy::S3Fifo);
    let cache = rig.cache.as_ref().expect("cache layer missing");
    assert_eq!(cache.policy(), CachePolicy::S3Fifo);
    let batches: Vec<Batch> = rig.dataloader.epoch(0).collect();
    assert_eq!(batches.len(), 2);
    cache.audit().unwrap();
    p.audit().unwrap();
}
