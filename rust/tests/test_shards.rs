//! Shard-streaming correctness (PR 7): a [`cdl::dataset::ShardDataset`]
//! over packed tar windows is **byte-identical** to the per-file
//! [`cdl::dataset::ImageFolderDataset`] over the source corpus for
//! every fetcher × dispatch mode, through pipelined epoch seams; the
//! full rig (prefetch + shard windows + item stealing + consumer
//! credit) amortizes remote requests without changing a single
//! delivered byte; the two-level shard shuffle covers every sample
//! exactly once; and the tar container round-trips and rejects
//! truncated or corrupt archives instead of serving garbage.

use std::sync::Arc;
use std::time::Duration;

use cdl::data::synth::{generate_corpus, CorpusSpec};
use cdl::data::AugmentConfig;
use cdl::dataloader::{Batch, Dataloader, DataloaderConfig, FetchImpl};
use cdl::dataset::{Dataset, ImageFolderDataset, ShardDataset};
use cdl::shards::{pack_shards, read_tar, write_tar, ShardStore, TarEntry};
use cdl::storage::{MemStore, ObjectStore};
use cdl::telemetry::Recorder;

const ITEMS: usize = 37; // not a multiple of the batch size: partial tail
const BATCH: usize = 8;
const SHARD: usize = 6; // not a divisor of ITEMS: ragged last shard
const EPOCHS: usize = 3;

/// (work_stealing, steal_items) per dispatch mode.
const DISPATCH: [(bool, bool); 3] = [(false, false), (true, false), (true, true)];

/// The per-file dataset over a fresh corpus and the shard dataset over
/// the same corpus packed into `SHARD`-sample tar windows.
fn dataset_pair() -> (Arc<dyn Dataset>, Arc<dyn Dataset>) {
    let src: Arc<dyn ObjectStore> = Arc::new(MemStore::new("src"));
    generate_corpus(&src, &CorpusSpec::tiny(ITEMS)).unwrap();
    let dst: Arc<dyn ObjectStore> = Arc::new(MemStore::new("dst"));
    let manifest = pack_shards(&src, &dst, SHARD).unwrap();
    let cfg = AugmentConfig { crop: 16, ..Default::default() };
    let per_file: Arc<dyn Dataset> =
        Arc::new(ImageFolderDataset::new(src, cfg.clone()));
    let sharded: Arc<dyn Dataset> = Arc::new(ShardDataset::new(
        Arc::new(ShardStore::new(dst, manifest, 3)),
        cfg,
    ));
    (per_file, sharded)
}

fn loader(
    ds: &Arc<dyn Dataset>,
    fetch: FetchImpl,
    (work_stealing, steal_items): (bool, bool),
) -> Dataloader {
    Dataloader::new(
        ds.clone(),
        DataloaderConfig {
            batch_size: BATCH,
            num_workers: 3,
            fetch_impl: fetch,
            num_fetch_workers: 4,
            arena_slabs: 12,
            work_stealing,
            steal_items,
            consumer_credit: 3,
            epoch_pipeline: 1,
            spawn_cost_override: Some(Duration::ZERO),
            ..Default::default()
        },
        Recorder::new(),
    )
}

fn assert_batches_identical(a: &[Batch], b: &[Batch], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: batch count");
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.id, y.id, "{ctx}");
        assert_eq!(x.images.shape, y.images.shape, "{ctx}: batch {}", x.id);
        assert_eq!(x.images.data, y.images.data, "{ctx}: batch {} bytes", x.id);
        assert_eq!(x.labels, y.labels, "{ctx}: batch {}", x.id);
        assert_eq!(x.indices, y.indices, "{ctx}: batch {}", x.id);
        assert_eq!(x.raw_bytes, y.raw_bytes, "{ctx}: batch {}", x.id);
    }
}

#[test]
fn shard_loader_matches_per_file_across_fetchers_and_dispatch() {
    // every fetcher × every dispatch mode, epoch pipelining on: the
    // shard-streamed loader must emit the exact same pipelined
    // multi-epoch batch stream as the per-file loader — the sample keys,
    // index mapping, and augmentation stream are identical, so storage
    // layout must be invisible to the consumer
    let (per_file, sharded) = dataset_pair();
    for fetch in FetchImpl::all() {
        for dispatch in DISPATCH {
            let pf = loader(&per_file, fetch, dispatch);
            let sh = loader(&sharded, fetch, dispatch);
            for epoch in 0..EPOCHS {
                let a: Vec<Batch> = pf.epoch(epoch).collect();
                let b: Vec<Batch> = sh.epoch(epoch).collect();
                assert_eq!(a.last().unwrap().len(), ITEMS % BATCH); // partial tail
                assert_batches_identical(
                    &a,
                    &b,
                    &format!("{} {dispatch:?} epoch {epoch}", fetch.label()),
                );
                for batch in a.into_iter().chain(b) {
                    batch.recycle();
                }
            }
        }
    }
}

#[test]
fn shard_rig_spans_epoch_seams_with_fewer_remote_requests() {
    // full-stack: simulated s3 behind prefetch, item stealing, consumer
    // credit, epoch pipelining — shard mode must deliver byte-identical
    // batches across three pipelined epochs while issuing a fraction of
    // the per-file remote request count, and the reorder buffer must
    // respect the credit bound through the seams
    const CREDIT: usize = 4;
    let spec_for = |shard_size: usize| {
        let mut spec = cdl::bench::rig::RigSpec::quick("s3", 0.02);
        spec.items = 48;
        spec.batch_size = 8;
        spec.num_workers = 3;
        spec.fetch_impl = FetchImpl::Threaded;
        spec.num_fetch_workers = 8;
        spec.prefetch_depth = 48;
        spec.arena_slabs = 12;
        spec.work_stealing = true;
        spec.steal_items = true;
        spec.consumer_credit = CREDIT;
        spec.epoch_pipeline = 1;
        spec.shard_size = shard_size;
        spec
    };
    let pf_rig = cdl::bench::rig::build(&spec_for(0)).unwrap();
    let sh_rig = cdl::bench::rig::build(&spec_for(12)).unwrap();
    assert!(pf_rig.shards.is_none());
    let shards = sh_rig.shards.as_ref().expect("shard rig without a ShardStore");
    assert_eq!(shards.manifest().n_shards(), 4);

    for epoch in 0..EPOCHS {
        let ctx = format!("epoch {epoch}");
        let mut a_it = pf_rig.dataloader.epoch(epoch);
        let a: Vec<Batch> = a_it.by_ref().collect();
        let mut b_it = sh_rig.dataloader.epoch(epoch);
        let b: Vec<Batch> = b_it.by_ref().collect();
        let hwm = b_it.reorder_high_water();
        assert!(hwm <= CREDIT, "{ctx}: reorder hwm {hwm} > credit {CREDIT}");
        assert_batches_identical(&a, &b, &ctx);
        for batch in a.into_iter().chain(b) {
            batch.recycle();
        }
    }

    // request amortization: per-file pays at least one remote GET per
    // sample (the prefetch hot tier then retains this tiny corpus across
    // epochs); shard mode pays at most one GET per window per epoch —
    // 4× fewer requests even in the worst case
    let pf_gets = pf_rig.remote.as_ref().unwrap().stats().gets;
    let sh_gets = sh_rig.remote.as_ref().unwrap().stats().gets;
    assert!(pf_gets >= 48, "per-file issued only {pf_gets} remote GETs");
    assert!(
        sh_gets <= (4 * EPOCHS) as u64,
        "shard mode issued {sh_gets} remote GETs for 4 windows × {EPOCHS} epochs"
    );
    assert!(
        sh_gets * 4 <= pf_gets,
        "no request amortization: {sh_gets} shard GETs vs {pf_gets} per-file"
    );
    let (fetches, hits, _, _) = shards.window_stats();
    assert!(
        fetches <= (4 * EPOCHS) as u64,
        "window cache thrashed: {fetches} fetches for 4 windows"
    );
    assert!(hits > fetches, "window cache never amortized: {hits} hits");
}

#[test]
fn shard_shuffle_rig_covers_every_sample_and_varies_by_epoch() {
    // two-level shuffle at the rig level: every epoch delivers each
    // sample exactly once, consecutive epochs visit in different orders,
    // and the same seed reproduces the same order on a fresh rig
    let spec = {
        let mut spec = cdl::bench::rig::RigSpec::quick("mem", 0.0);
        spec.items = 40;
        spec.batch_size = 8;
        spec.num_workers = 2;
        spec.arena_slabs = 8;
        spec.shard_size = 8;
        spec.shard_shuffle = true;
        spec
    };
    let order_of = |rig: &cdl::bench::rig::Rig, epoch: usize| -> Vec<usize> {
        let mut order = Vec::new();
        for b in rig.dataloader.epoch(epoch) {
            order.extend(b.indices.iter().copied());
            b.recycle();
        }
        order
    };
    let rig = cdl::bench::rig::build(&spec).unwrap();
    let mut orders = Vec::new();
    for epoch in 0..EPOCHS {
        let order = order_of(&rig, epoch);
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..40).collect::<Vec<_>>(), "epoch {epoch} coverage");
        orders.push(order);
    }
    assert_ne!(orders[0], orders[1], "shuffle is epoch-invariant");
    assert_ne!(orders[1], orders[2], "shuffle is epoch-invariant");
    let again = cdl::bench::rig::build(&spec).unwrap();
    assert_eq!(orders[0], order_of(&again, 0), "same seed, same order");
}

#[test]
fn tar_round_trips_and_rejects_damage() {
    let entries = vec![
        TarEntry { name: "a/0.simg".into(), data: vec![1, 2, 3] },
        TarEntry { name: "a/1.simg".into(), data: vec![] }, // empty member
        TarEntry { name: "b/2.simg".into(), data: vec![9; 1000] }, // >1 block
    ];
    let buf = write_tar(&entries).unwrap();
    assert_eq!(read_tar(&buf).unwrap(), entries);

    // truncation mid-member must be an error naming the member, never a
    // silent short read
    let cut = buf.len() - 1536; // into the last member's data blocks
    let err = read_tar(&buf[..cut]).unwrap_err().to_string();
    assert!(err.contains("truncated"), "{err}");
    assert!(err.contains("b/2.simg"), "{err}");

    // a flipped byte in a header must fail the checksum
    let mut bad = buf.clone();
    bad[0] ^= 0xFF;
    let err = read_tar(&bad).unwrap_err().to_string();
    assert!(err.contains("checksum"), "{err}");

    // names beyond the ustar field are rejected at write time
    let long = TarEntry { name: "x".repeat(101), data: vec![] };
    let err = write_tar(std::slice::from_ref(&long)).unwrap_err().to_string();
    assert!(err.contains("name too long"), "{err}");
}
