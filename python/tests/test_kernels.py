"""Kernel-vs-oracle correctness: the CORE L1 signal.

Hypothesis sweeps shapes/dtypes of both Pallas kernels against the pure-jnp
oracles in ``compile.kernels.ref`` (assert_allclose)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import matmul as kmm
from compile.kernels import normalize as knorm
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# normalize
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(1, 5),
    h=st.integers(1, 40),
    w=st.integers(1, 24),
    block_h=st.integers(1, 16),
    u8=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_normalize_matches_ref(b, h, w, block_h, u8, seed):
    rng = np.random.RandomState(seed)
    if u8:
        x = rng.randint(0, 256, size=(b, h, w, 3), dtype=np.uint8)
    else:
        x = rng.rand(b, h, w, 3).astype(np.float32)
    got = np.asarray(knorm.normalize(jnp.asarray(x), block_h=block_h))
    want = np.asarray(ref.normalize_ref(x))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    assert got.dtype == np.float32


@pytest.mark.parametrize("b,h,w", [(1, 1, 1), (8, 64, 64), (2, 7, 129)])
def test_normalize_shapes(b, h, w):
    x = np.zeros((b, h, w, 3), np.uint8)
    out = np.asarray(knorm.normalize(jnp.asarray(x)))
    assert out.shape == (b, h, w, 3)
    # all-zero u8 maps to (0 - mean)/std
    want = np.asarray(ref.normalize_ref(x))
    np.testing.assert_allclose(out, want, rtol=1e-6)


def test_normalize_custom_stats():
    x = np.full((1, 4, 4, 3), 128, np.uint8)
    out = np.asarray(
        knorm.normalize(jnp.asarray(x), mean=(0.5, 0.5, 0.5), std=(0.5, 0.5, 0.5))
    )
    want = (128.0 / 255.0 - 0.5) / 0.5
    np.testing.assert_allclose(out, np.full_like(out, want), rtol=2e-5, atol=1e-6)


def test_normalize_rejects_bad_rank():
    with pytest.raises(ValueError):
        knorm.normalize(jnp.zeros((4, 4, 3), jnp.uint8))
    with pytest.raises(ValueError):
        knorm.normalize(jnp.zeros((1, 4, 4, 4), jnp.uint8))


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 160),
    n=st.integers(1, 200),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.RandomState(seed)
    a = rng.randn(m, k).astype(np.float32)
    b = rng.randn(k, n).astype(np.float32)
    got = np.asarray(kmm.matmul(jnp.asarray(a), jnp.asarray(b)))
    want = np.asarray(ref.matmul_ref(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@settings(**SETTINGS)
@given(
    bm=st.sampled_from([8, 32, 64, 128]),
    bn=st.sampled_from([8, 32, 128]),
    bk=st.sampled_from([8, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_tile_shapes(bm, bn, bk, seed):
    rng = np.random.RandomState(seed)
    a = rng.randn(64, 48).astype(np.float32)
    b = rng.randn(48, 96).astype(np.float32)
    got = np.asarray(kmm.matmul(jnp.asarray(a), jnp.asarray(b), bm=bm, bn=bn, bk=bk))
    want = np.asarray(ref.matmul_ref(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_matmul_bf16_inputs():
    rng = np.random.RandomState(0)
    a = jnp.asarray(rng.randn(32, 32), jnp.bfloat16)
    b = jnp.asarray(rng.randn(32, 32), jnp.bfloat16)
    got = np.asarray(kmm.matmul(a, b))
    want = np.asarray(ref.matmul_ref(a, b))
    assert got.dtype == np.float32
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_matmul_rejects_bad_shapes():
    with pytest.raises(ValueError):
        kmm.matmul(jnp.zeros((2, 3)), jnp.zeros((4, 5)))
    with pytest.raises(ValueError):
        kmm.matmul(jnp.zeros((2,)), jnp.zeros((2, 2)))


def test_vmem_estimate_default_tiles_fit():
    # 3 tiles of 128x128 f32 = 192 KiB — comfortably inside 16 MiB VMEM.
    assert kmm.vmem_bytes() == 3 * 128 * 128 * 4
    assert kmm.vmem_bytes() < 16 * 1024 * 1024
