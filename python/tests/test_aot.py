"""AOT emission tests: HLO text artifacts are parseable and complete."""

import os
import subprocess
import sys

import pytest

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_lower_normalize_emits_hlo_text():
    text = aot.lower_normalize(1, 8)
    assert text.startswith("HloModule")
    assert "parameter" in text


def test_lower_matmul_emits_hlo_text():
    text = aot.lower_matmul(16)
    assert text.startswith("HloModule")
    # tuple return contract for the rust side (return_tuple=True)
    assert "ROOT" in text


def test_lower_train_has_all_params():
    text = aot.lower_train(2, 16)
    assert text.startswith("HloModule")
    # params + images + labels parameters present
    n_inputs = len(model.param_specs()) + 2
    for i in range(n_inputs):
        assert f"parameter({i})" in text, f"missing parameter({i})"
    assert "u8[2,16,16,3]" in text
    assert "s32[2]" in text


def test_lower_init_no_inputs():
    text = aot.lower_init()
    assert text.startswith("HloModule")
    # the ENTRY computation takes no arguments (internal while-loop
    # computations do have parameters)
    lines = text.splitlines()
    start = next(i for i, l in enumerate(lines) if l.startswith("ENTRY"))
    entry_body = []
    for l in lines[start + 1 :]:
        if l.startswith("}"):
            break
        entry_body.append(l)
    assert not any("parameter(" in l for l in entry_body)


def test_smoke_numbers_first_loss_reasonable():
    losses = aot.smoke_numbers(4, 16, steps=1)
    import numpy as np

    assert abs(losses[0] - np.log(model.NUM_CLASSES)) < 10.0


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_manifest_matches_model():
    import json

    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["model"]["num_params"] == model.num_params()
    assert len(man["model"]["params"]) == len(model.param_specs())
    for art in man["artifacts"].values():
        assert os.path.exists(os.path.join(ART, art["file"])), art["file"]
