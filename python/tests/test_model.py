"""L2 model tests: shapes, gradients, SGD semantics, determinism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


@pytest.fixture(scope="module")
def params():
    return model.init_params(0)


def test_param_specs_match_init(params):
    specs = model.param_specs()
    assert len(specs) == len(params)
    for (name, shape), p in zip(specs, params):
        assert tuple(p.shape) == tuple(shape), name
        assert p.dtype == jnp.float32


def test_num_params_consistent(params):
    assert model.num_params() == sum(int(np.prod(p.shape)) for p in params)
    # sanity: the scaled ResNet is ~0.5M params
    assert 100_000 < model.num_params() < 5_000_000


def test_forward_shapes(params):
    imgs, _ = model.make_example_batch(2, 32)
    logits = model.forward(params, imgs)
    assert logits.shape == (2, model.NUM_CLASSES)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_forward_batch_independence(params):
    """Row i of the logits must not depend on other rows of the batch."""
    imgs, _ = model.make_example_batch(4, 32)
    full = np.asarray(model.forward(params, imgs))
    solo = np.asarray(model.forward(params, imgs[:1]))
    np.testing.assert_allclose(full[:1], solo, rtol=1e-4, atol=1e-5)


def test_initial_loss_near_log_c(params):
    imgs, labels = model.make_example_batch(8, 32)
    loss = model.loss_fn(params, imgs, labels)
    # untrained logits ≈ uniform → loss ≈ ln(NUM_CLASSES) within a few nats
    assert abs(float(loss) - np.log(model.NUM_CLASSES)) < 10.0


def test_train_step_decreases_loss_on_fixed_batch(params):
    imgs, labels = model.make_example_batch(8, 32)
    p = list(params)
    losses = []
    for _ in range(3):
        out = model.train_step(p, imgs, labels)
        p, loss = list(out[:-1]), float(out[-1])
        losses.append(loss)
    assert losses[-1] < losses[0], losses


def test_train_step_applies_weight_decay(params):
    """With zero-gradient directions, params still shrink by lr*wd."""
    imgs, labels = model.make_example_batch(4, 32)
    out = model.train_step(params, imgs, labels)
    new_params = out[:-1]
    # head bias for classes never present in labels still decays
    old = np.asarray(params[-1])
    new = np.asarray(new_params[-1])
    assert new.shape == old.shape


def test_train_step_deterministic(params):
    imgs, labels = model.make_example_batch(4, 32)
    a = model.train_step(params, imgs, labels)
    b = model.train_step(params, imgs, labels)
    np.testing.assert_array_equal(np.asarray(a[-1]), np.asarray(b[-1]))


def test_grads_flow_to_all_params(params):
    imgs, labels = model.make_example_batch(4, 32)
    grads = jax.grad(model.loss_fn)(params, imgs, labels)
    specs = model.param_specs()
    for (name, _), g in zip(specs, grads):
        assert bool(jnp.all(jnp.isfinite(g))), name
        # every parameter should receive some gradient signal
        assert float(jnp.max(jnp.abs(g))) > 0.0, f"dead gradient: {name}"


def test_example_batch_pattern():
    imgs, labels = model.make_example_batch(2, 8)
    assert imgs.dtype == jnp.uint8 and labels.dtype == jnp.int32
    flat = np.asarray(imgs).reshape(-1)
    # spot-check the Knuth-hash pattern contract used by rust tests
    for i in [0, 1, 17, 100]:
        want = (i * 2654435761) % (2**32) % 256
        assert flat[i] == want
