"""AOT lowering: JAX/Pallas → HLO *text* artifacts for the rust runtime.

Interchange format is HLO text, NOT ``.serialize()``: jax ≥ 0.5 emits
HloModuleProtos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published ``xla`` crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Emitted into ``artifacts/``:

* ``init.hlo.txt``                 — () → (params...,)
* ``train_step_b{B}_i{I}.hlo.txt`` — (params..., u8 images, i32 labels)
                                      → (params..., loss)
* ``forward_b{B}_i{I}.hlo.txt``    — (params..., u8 images) → (logits,)
* ``normalize_b{B}_i{I}.hlo.txt``  — kernel-only artifact for rust-side
                                      numeric cross-checks
* ``matmul_{N}.hlo.txt``           — ditto for the tiled matmul kernel
* ``manifest.json``                — param order/shapes, variant arg specs,
                                      and smoke numbers (expected losses on a
                                      deterministic batch) the rust tests
                                      assert against.

Run via ``make artifacts`` (build-time only; python never runs on the
request path).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import matmul as kmatmul
from .kernels import normalize as knorm

# (batch, image_side) variants compiled for the rust runtime.
TRAIN_VARIANTS = [(8, 32), (16, 64), (32, 64)]
FORWARD_VARIANTS = [(16, 64)]


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _param_arg_specs():
    return [
        jax.ShapeDtypeStruct(shape, jnp.float32)
        for _, shape in model.param_specs()
    ]


def _write(out_dir: str, name: str, text: str) -> str:
    path = os.path.join(out_dir, name)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {name} ({len(text) / 1024:.0f} KiB)")
    return name


def lower_train(batch: int, img: int) -> str:
    specs = (
        _param_arg_specs(),
        jax.ShapeDtypeStruct((batch, img, img, 3), jnp.uint8),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
    )
    return to_hlo_text(jax.jit(model.train_step).lower(*specs))


def lower_forward(batch: int, img: int) -> str:
    specs = (
        _param_arg_specs(),
        jax.ShapeDtypeStruct((batch, img, img, 3), jnp.uint8),
    )
    return to_hlo_text(jax.jit(model.eval_step).lower(*specs))


def lower_init() -> str:
    return to_hlo_text(jax.jit(lambda: tuple(model.init_params(0))).lower())


def lower_normalize(batch: int, img: int) -> str:
    spec = jax.ShapeDtypeStruct((batch, img, img, 3), jnp.uint8)
    return to_hlo_text(
        jax.jit(lambda x: (knorm.normalize(x),)).lower(spec)
    )


def lower_matmul(n: int) -> str:
    spec = jax.ShapeDtypeStruct((n, n), jnp.float32)
    return to_hlo_text(
        jax.jit(lambda a, b: (kmatmul.matmul(a, b),)).lower(spec, spec)
    )


def smoke_numbers(batch: int, img: int, steps: int = 2):
    """Expected losses for a deterministic batch — asserted by rust tests."""
    params = model.init_params(0)
    images, labels = model.make_example_batch(batch, img)
    losses = []
    for _ in range(steps):
        out = model.train_step(params, images, labels)
        params, loss = list(out[:-1]), out[-1]
        losses.append(float(loss))
    return losses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--skip-smoke", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {
        "model": {
            "widths": list(model.WIDTHS),
            "num_classes": model.NUM_CLASSES,
            "lr": model.LR,
            "weight_decay": model.WEIGHT_DECAY,
            "num_params": model.num_params(),
            "params": [
                {"name": n, "shape": list(s)} for n, s in model.param_specs()
            ],
        },
        "artifacts": {},
    }

    print("AOT lowering (HLO text):")
    manifest["artifacts"]["init"] = {
        "file": _write(args.out, "init.hlo.txt", lower_init()),
        "inputs": [],
        "outputs": "params",
    }
    for b, i in TRAIN_VARIANTS:
        name = f"train_step_b{b}_i{i}"
        manifest["artifacts"][name] = {
            "file": _write(args.out, name + ".hlo.txt", lower_train(b, i)),
            "batch": b,
            "image": i,
            "inputs": "params + images(u8 NHWC) + labels(i32)",
            "outputs": "params + loss",
        }
    for b, i in FORWARD_VARIANTS:
        name = f"forward_b{b}_i{i}"
        manifest["artifacts"][name] = {
            "file": _write(args.out, name + ".hlo.txt", lower_forward(b, i)),
            "batch": b,
            "image": i,
        }
    manifest["artifacts"]["normalize_b4_i32"] = {
        "file": _write(args.out, "normalize_b4_i32.hlo.txt", lower_normalize(4, 32)),
        "batch": 4,
        "image": 32,
    }
    manifest["artifacts"]["matmul_128"] = {
        "file": _write(args.out, "matmul_128.hlo.txt", lower_matmul(128)),
        "n": 128,
    }

    if not args.skip_smoke:
        b, i = TRAIN_VARIANTS[0]
        losses = smoke_numbers(b, i)
        manifest["smoke"] = {
            "variant": f"train_step_b{b}_i{i}",
            "batch": b,
            "image": i,
            "losses": losses,
            "rtol": 2e-4,
        }
        print(f"  smoke losses ({b}x{i}): {losses}")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"  wrote manifest.json (num_params={model.num_params()})")


if __name__ == "__main__":
    main()
