"""Layer-1 Pallas kernels (build-time only; lowered with interpret=True).

The data pipeline's on-device compute hot-spots:

* :mod:`.normalize` — fused ``to_tensor + normalize`` stage of the paper's
  augmentation pipeline (the only augmentation step that is pure per-pixel
  math and therefore belongs on the device, fused into the train step).
* :mod:`.matmul` — MXU-style tiled matmul used for the classifier head.

Pure-jnp oracles live in :mod:`.ref`; pytest/hypothesis checks every kernel
against its oracle across shapes and dtypes.
"""

from . import matmul, normalize, ref  # noqa: F401
