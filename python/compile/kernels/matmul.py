"""MXU-style tiled matmul Pallas kernel (classifier head of the L2 model).

The paper's model is ResNet-18; its dense head (and, after im2col, any conv)
bottoms out in matmul. We implement the canonical Pallas tiled matmul:
grid ``(M/bm, N/bn, K/bk)`` with an output-tile accumulator that is zeroed
at ``k == 0`` and accumulated across the K axis — the HBM→VMEM schedule a
CUDA kernel would express with threadblocks is expressed with BlockSpecs.

Default tile 128×128×128 matches the MXU systolic array; on CPU we lower
with ``interpret=True``. Shapes that are not tile multiples are padded by
the :func:`matmul` wrapper and sliced back (zero padding is exact for
matmul).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref, *, k_steps):
    """One (bm, bn) output tile; accumulates over the K grid axis."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _pad_to(x, m0, m1):
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 == 0 and p1 == 0:
        return x
    return jnp.pad(x, ((0, p0), (0, p1)))


def matmul(a, b, *, bm=128, bn=128, bk=128):
    """``a @ b`` via the tiled Pallas kernel, f32 accumulate.

    ``a``: (M, K), ``b``: (K, N); any float dtype, output f32. Shapes are
    padded up to tile multiples and the result sliced back.
    """
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise ValueError(f"bad matmul shapes {a.shape} @ {b.shape}")
    m, k = a.shape
    _, n = b.shape
    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, k)
    # Tiles must still be hardware-friendly when inputs are tiny: round the
    # effective tile up to at least 8 in the sublane dim.
    ap = _pad_to(a.astype(jnp.float32), bm_, bk_)
    bp = _pad_to(b.astype(jnp.float32), bk_, bn_)
    mp, kp = ap.shape
    _, np_ = bp.shape
    grid = (mp // bm_, np_ // bn_, kp // bk_)

    kernel = functools.partial(_matmul_kernel, k_steps=grid[2])
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(ap, bp)
    return out[:m, :n]


def vmem_bytes(bm=128, bn=128, bk=128, dtype_bytes=4):
    """VMEM footprint estimate for one grid step (DESIGN.md §Perf)."""
    return (bm * bk + bk * bn + bm * bn) * dtype_bytes
