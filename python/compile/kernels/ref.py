"""Pure-jnp oracles for the Pallas kernels.

These are the *correctness ground truth*: every Pallas kernel must match its
oracle to float tolerance across the shape/dtype sweeps in
``python/tests/test_kernels.py`` (hypothesis) before it is allowed into the
AOT artifacts.
"""

import jax.numpy as jnp

# ImageNet channel statistics used by the paper's transform
# (torchvision.transforms.Normalize defaults).
IMAGENET_MEAN = (0.485, 0.456, 0.406)
IMAGENET_STD = (0.229, 0.224, 0.225)


def normalize_ref(x, mean=IMAGENET_MEAN, std=IMAGENET_STD):
    """to_tensor + normalize oracle.

    ``x`` is an NHWC image batch, u8 in [0,255] or float already in [0,1].
    Returns f32 NHWC, per-channel ``(x/255 - mean)/std``.
    """
    x = jnp.asarray(x)
    if x.dtype == jnp.uint8:
        xf = x.astype(jnp.float32) / 255.0
    else:
        xf = x.astype(jnp.float32)
    mean = jnp.asarray(mean, jnp.float32).reshape((1, 1, 1, 3))
    std = jnp.asarray(std, jnp.float32).reshape((1, 1, 1, 3))
    return (xf - mean) / std


def matmul_ref(a, b):
    """f32 matmul oracle: ``a @ b`` with f32 accumulation."""
    return jnp.matmul(
        a.astype(jnp.float32),
        b.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
