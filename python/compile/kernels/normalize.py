"""Fused ``to_tensor + normalize`` as a Pallas kernel.

The paper's Dataset transform ends with ``ToTensor() ∘ Normalize(mean,std)``
— pure per-pixel math. In our three-layer port this is the stage that moves
*onto the device*: the rust loader ships raw u8 crops, and the train step's
first op is this kernel, fused into the same HLO module as the model.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the batch is tiled over
(image-rows × lane) blocks so each grid step streams one ``(block_h, W*C)``
tile HBM→VMEM, normalizes in-register, and writes back — a pure
VPU-elementwise kernel with an (8,128)-friendly trailing layout. On CPU we
lower with ``interpret=True`` (Mosaic custom-calls cannot run on the CPU
PJRT plugin).

Pallas kernels may not capture array constants, so the channel mean/std
enter as tiny broadcast operands (every grid step maps to the same
(1,1,1,3) block).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import IMAGENET_MEAN, IMAGENET_STD


def _normalize_kernel(x_ref, m_ref, s_ref, o_ref, *, scale):
    """One (1, block_h, W, C) tile: o = (x*scale - mean) / std."""
    x = x_ref[...].astype(jnp.float32) * scale
    o_ref[...] = (x - m_ref[...]) * s_ref[...]


def normalize(x, mean=IMAGENET_MEAN, std=IMAGENET_STD, block_h=8):
    """Pallas fused normalize over an NHWC batch (u8 or float).

    Grid: ``(B, ceil(H / block_h))``; each step handles one ``block_h``-row
    slab of one image. W and C ride along whole (C=3, W is the lane dim).
    """
    if x.ndim != 4 or x.shape[-1] != 3:
        raise ValueError(f"expected NHWC with C=3, got {x.shape}")
    b, h, w, c = x.shape
    scale = 1.0 / 255.0 if x.dtype == jnp.uint8 else 1.0
    block_h = min(block_h, h)
    grid = (b, pl.cdiv(h, block_h))

    m = jnp.asarray(mean, jnp.float32).reshape((1, 1, 1, 3))
    inv_s = (1.0 / jnp.asarray(std, jnp.float32)).reshape((1, 1, 1, 3))

    kernel = functools.partial(_normalize_kernel, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_h, w, c), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, 1, c), lambda i, j: (0, 0, 0, 0)),
            pl.BlockSpec((1, 1, 1, c), lambda i, j: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_h, w, c), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, w, c), jnp.float32),
        interpret=True,
    )(x, m, inv_s)
