"""Layer-2 JAX model: a width/depth-scaled ResNet classifier.

The paper trains ResNet-18 on ImageNet (batch 256, 224x224) on a V100. For
the CPU-PJRT reproduction we keep the same *structure* — residual CNN,
cross-entropy, SGD(lr, weight-decay) — scaled to run a real train step in
tens of milliseconds: 3 residual stages (widths 32/64/128), 64x64 inputs,
~0.6M params (a "ResNet-10"). DESIGN.md documents the substitution.

The train step is ONE fused computation: pallas-normalize(u8 images) →
forward → cross-entropy → backward → SGD update. It is AOT-lowered by
``aot.py`` to HLO text and executed from rust via PJRT; python never runs
at load/serve time.

Layer-1 kernels used here (lowered into the same HLO):
* ``kernels.normalize`` — fused to_tensor+normalize on the u8 input batch.
* ``kernels.matmul`` — tiled classifier-head matmul (with a custom VJP so
  the backward pass also runs through the Pallas kernel).
"""

import functools

import numpy as np
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import matmul as pmatmul_mod
from .kernels import normalize as pnorm_mod

# ---------------------------------------------------------------------------
# Architecture configuration
# ---------------------------------------------------------------------------

WIDTHS = (32, 64, 128)  # stage widths (stride-2 between stages)
NUM_CLASSES = 512  # synthetic label space (tile-friendly head)
# The paper's Table 2 uses lr=0.1 for ResNet-18/batch-256; the scaled
# CPU model diverges there — 0.02 gives stable descent (DESIGN.md §4).
LR = 0.02
WEIGHT_DECAY = 1e-4  # paper Table 2


# ---------------------------------------------------------------------------
# Pallas matmul with custom VJP (backward also uses the kernel)
# ---------------------------------------------------------------------------


@jax.custom_vjp
def pallas_matmul(a, b):
    return pmatmul_mod.matmul(a, b)


def _mm_fwd(a, b):
    return pmatmul_mod.matmul(a, b), (a, b)


def _mm_bwd(res, g):
    a, b = res
    da = pmatmul_mod.matmul(g, b.T)
    db = pmatmul_mod.matmul(a.T, g)
    return da, db


pallas_matmul.defvjp(_mm_fwd, _mm_bwd)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def param_specs() -> List[Tuple[str, Tuple[int, ...]]]:
    """Deterministic (name, shape) list — the flattening order used for the
    PJRT interface; the rust runtime reads the same order from the manifest."""
    specs: List[Tuple[str, Tuple[int, ...]]] = []
    specs.append(("stem/w", (3, 3, 3, WIDTHS[0])))
    specs.append(("stem/b", (WIDTHS[0],)))
    c_in = WIDTHS[0]
    for si, c in enumerate(WIDTHS):
        if c != c_in:
            specs.append((f"s{si}/down/w", (3, 3, c_in, c)))
            specs.append((f"s{si}/down/b", (c,)))
        specs.append((f"s{si}/res/w1", (3, 3, c, c)))
        specs.append((f"s{si}/res/b1", (c,)))
        specs.append((f"s{si}/res/w2", (3, 3, c, c)))
        specs.append((f"s{si}/res/b2", (c,)))
        c_in = c
    specs.append(("head/w", (WIDTHS[-1], NUM_CLASSES)))
    specs.append(("head/b", (NUM_CLASSES,)))
    return specs


def init_params(seed: int = 0) -> List[jnp.ndarray]:
    """He-init parameters, flattened in `param_specs()` order."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in param_specs():
        key, sub = jax.random.split(key)
        if name.endswith("/b"):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = 1
            for d in shape[:-1]:
                fan_in *= d
            std = (2.0 / fan_in) ** 0.5
            params.append(std * jax.random.normal(sub, shape, jnp.float32))
    return params


def num_params() -> int:
    n = 0
    for _, shape in param_specs():
        size = 1
        for d in shape:
            size *= d
        n += size
    return n


def _as_dict(flat: List[jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    return {name: p for (name, _), p in zip(param_specs(), flat)}


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _conv(x, w, b, stride=1):
    y = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + b.reshape((1, 1, 1, -1))


def forward(flat_params: List[jnp.ndarray], images_u8: jnp.ndarray) -> jnp.ndarray:
    """u8 NHWC images → logits (B, NUM_CLASSES)."""
    p = _as_dict(flat_params)
    x = pnorm_mod.normalize(images_u8)  # L1 kernel, fused into this HLO
    x = jax.nn.relu(_conv(x, p["stem/w"], p["stem/b"]))
    c_in = WIDTHS[0]
    for si, c in enumerate(WIDTHS):
        if c != c_in:
            x = jax.nn.relu(_conv(x, p[f"s{si}/down/w"], p[f"s{si}/down/b"], stride=2))
        h = jax.nn.relu(_conv(x, p[f"s{si}/res/w1"], p[f"s{si}/res/b1"]))
        h = _conv(h, p[f"s{si}/res/w2"], p[f"s{si}/res/b2"])
        x = jax.nn.relu(x + h)
        c_in = c
    x = jnp.mean(x, axis=(1, 2))  # global average pool -> (B, C)
    logits = pallas_matmul(x, p["head/w"]) + p["head/b"]  # L1 kernel
    return logits


def loss_fn(flat_params, images_u8, labels):
    logits = forward(flat_params, images_u8)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# Train / eval steps (the AOT entry points)
# ---------------------------------------------------------------------------


def train_step(flat_params, images_u8, labels):
    """One fused SGD step. Returns (new_params..., loss)."""
    loss, grads = jax.value_and_grad(loss_fn)(flat_params, images_u8, labels)
    new_params = [
        p - LR * (g + WEIGHT_DECAY * p) for p, g in zip(flat_params, grads)
    ]
    return tuple(new_params) + (loss,)


def eval_step(flat_params, images_u8):
    """Forward only. Returns (logits,)."""
    return (forward(flat_params, images_u8),)


def make_example_batch(batch: int, img: int, seed: int = 1234):
    """Deterministic synthetic batch for smoke numbers in the manifest."""
    # Knuth-hash pattern with u32 wrap-around: reproducible bit-exactly on
    # the rust side (see rust/tests/test_runtime.rs).
    n = batch * img * img * 3
    idx = np.arange(n, dtype=np.uint32) * np.uint32(2654435761)
    images = (idx % np.uint32(256)).astype(np.uint8).reshape(
        (batch, img, img, 3)
    )
    labels = ((np.arange(batch, dtype=np.int32) * 7) % NUM_CLASSES).astype(
        np.int32
    )
    return jnp.asarray(images), jnp.asarray(labels)


train_step_jit = functools.partial(jax.jit(train_step, static_argnums=()))
