"""Build-time compile path: L2 JAX model + L1 Pallas kernels + AOT lowering.

Never imported at runtime — the rust binary only consumes the HLO-text
artifacts this package emits (see aot.py).
"""
