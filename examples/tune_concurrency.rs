//! Concurrency-parameter tuning (the paper's §3): sweep workers ×
//! fetchers on your storage profile and print the throughput heatmap so
//! you can pick the ridge — exactly what Fig 10/11 do.
//!
//! ```bash
//! cargo run --release --offline --example tune_concurrency -- --storage s3
//! ```

use cdl::bench::rig::{self, RigSpec};
use cdl::dataloader::FetchImpl;
use cdl::util::cli::Args;
use cdl::util::table::Table;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let p = Args::new("tune_concurrency", "workers × fetchers throughput sweep")
        .opt("storage", "s3", "s3|scratch|ceph_os|ceph_fs|gluster_fs")
        .opt("workers", "1,2,4,8", "worker counts")
        .opt("fetchers", "1,4,16", "fetcher counts")
        .opt("items", "96", "items per point")
        .parse(&argv)?;
    let workers = p.usize_list("workers")?;
    let fetchers = p.usize_list("fetchers")?;
    let storage: &'static str = Box::leak(p.get("storage").to_string().into_boxed_str());

    let header: Vec<String> = std::iter::once("workers\\fetchers".to_string())
        .chain(fetchers.iter().map(|f| f.to_string()))
        .collect();
    let mut t = Table::new_dyn(
        format!("{storage}: loader-only throughput (Mbit/s), threaded fetcher"),
        header,
    );
    let mut best = (0.0f64, 0usize, 0usize);
    for &w in &workers {
        let mut row = vec![w.to_string()];
        for &f in &fetchers {
            let mut spec = RigSpec::quick(storage, 0.2).with_impl(FetchImpl::Threaded);
            spec.items = p.usize("items")?;
            spec.batch_size = 16;
            spec.num_workers = w;
            spec.num_fetch_workers = f;
            let rig = rig::build(&spec)?;
            let (secs, bytes, _) = rig::drain_epoch(&rig);
            let mbit = cdl::util::fmt::mbit_s(bytes, secs);
            if mbit > best.0 {
                best = (mbit, w, f);
            }
            row.push(format!("{mbit:.0}"));
        }
        t.row(&row);
    }
    println!("{}", t.render());
    println!(
        "best: {:.0} Mbit/s at workers={}, fetchers={}",
        best.0, best.1, best.2
    );
    Ok(())
}
