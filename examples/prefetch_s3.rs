//! S3-profile training epochs with and without the sampler-ahead
//! prefetch engine (`cdl::prefetch`): same corpus, same loader, the only
//! difference is a `PrefetchStore` stacked on the storage. The engine
//! reads the sampler's epoch order published by the dataloader, fetches
//! ahead of demand through a bounded in-flight window, and lands results
//! in an in-memory hot tier — so demand lookups stop paying S3 first-byte
//! latency.
//!
//! ```bash
//! cargo run --release --offline --example prefetch_s3
//! ```

use std::sync::Arc;

use cdl::data::synth::{generate_corpus, CorpusSpec};
use cdl::data::AugmentConfig;
use cdl::dataloader::{Dataloader, DataloaderConfig};
use cdl::dataset::{Dataset, ImageFolderDataset};
use cdl::prefetch::{CachePolicy, PrefetchConfig, PrefetchStore};
use cdl::storage::{MemStore, ObjectStore, RemoteProfile, SimRemoteStore};
use cdl::telemetry::Recorder;

const ITEMS: usize = 192;
const BATCH: usize = 16;

/// Build corpus + simulated S3; optionally stack the prefetch engine.
fn build_loader(prefetch: bool) -> (Dataloader, Option<Arc<PrefetchStore>>) {
    let backing: Arc<dyn ObjectStore> = Arc::new(MemStore::new("corpus"));
    generate_corpus(
        &backing,
        &CorpusSpec { items: ITEMS, mean_bytes: 48 * 1024, ..Default::default() },
    )
    .expect("corpus");
    let remote: Arc<dyn ObjectStore> =
        SimRemoteStore::new(backing, RemoteProfile::s3().scaled(0.25), 42);

    let (store, engine): (Arc<dyn ObjectStore>, Option<Arc<PrefetchStore>>) =
        if prefetch {
            let p = PrefetchStore::new(
                remote,
                PrefetchConfig {
                    depth: 2 * BATCH, // the acceptance headline setting
                    max_inflight: 16,
                    policy: CachePolicy::TwoQ,
                    ..Default::default()
                },
            );
            (p.clone() as Arc<dyn ObjectStore>, Some(p))
        } else {
            (remote, None)
        };

    let dataset: Arc<dyn Dataset> = Arc::new(ImageFolderDataset::new(
        store,
        AugmentConfig { crop: 64, ..Default::default() },
    ));
    let loader = Dataloader::new(
        dataset,
        DataloaderConfig {
            batch_size: BATCH,
            num_workers: 2,
            // vanilla in-batch fetching: every bit of latency hiding in
            // this example comes from the prefetch engine
            ..Default::default()
        },
        Recorder::new(),
    );
    (loader, engine)
}

fn drain(loader: &Dataloader, epoch: usize) -> (f64, f64) {
    let t0 = std::time::Instant::now();
    let mut batch_lat = Vec::new();
    let mut it = loader.epoch(epoch);
    loop {
        let tb = std::time::Instant::now();
        if it.next().is_none() {
            break;
        }
        batch_lat.push(tb.elapsed().as_secs_f64());
    }
    drop(it);
    let mean =
        batch_lat.iter().sum::<f64>() / batch_lat.len().max(1) as f64;
    (t0.elapsed().as_secs_f64(), mean)
}

fn main() -> anyhow::Result<()> {
    println!("── without prefetch (simulated S3, vanilla fetcher) ──");
    let (plain, _) = build_loader(false);
    let (wall_off, mean_off) = drain(&plain, 0);
    println!(
        "epoch: {wall_off:.2}s wall, {:.0} ms mean batch latency",
        mean_off * 1e3
    );

    println!("\n── with prefetch (depth = 2×batch, 2Q hot tier) ──");
    let (fast, engine) = build_loader(true);
    let (wall_on, mean_on) = drain(&fast, 0);
    println!(
        "epoch: {wall_on:.2}s wall, {:.0} ms mean batch latency",
        mean_on * 1e3
    );

    if let Some(p) = &engine {
        println!("\n{}", p.summary_table("prefetch tiers").render());
    }
    println!(
        "mean batch latency: {:.1}× lower with the engine \
         (epoch wall: {:.1}× faster)",
        mean_off / mean_on,
        wall_off / wall_on
    );
    Ok(())
}
