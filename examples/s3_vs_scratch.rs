//! The paper's motivational experiment as a runnable example: the same
//! vanilla loader on local scratch vs S3-like storage, Torch vs
//! Lightning, then the fix (threaded fetcher) applied to S3.
//!
//! ```bash
//! cargo run --release --offline --example s3_vs_scratch
//! ```

use cdl::bench::rig::{self, RigSpec};
use cdl::dataloader::FetchImpl;
use cdl::trainer::TrainerKind;
use cdl::util::table::{num, Table};

fn main() -> anyhow::Result<()> {
    let mut t = Table::new(
        "motivational: where does the time go?",
        &["config", "runtime s", "img/s", "Mbit/s", "GPU idle %"],
    );
    let mut rows: Vec<(&str, RigSpec)> = Vec::new();
    for storage in ["scratch", "s3"] {
        for lib in [TrainerKind::Torch, TrainerKind::Lightning] {
            let mut spec = RigSpec::quick(storage, 0.2).with_trainer(lib);
            spec.items = 160;
            rows.push(("vanilla", spec));
        }
    }
    // the fix
    let mut fixed = RigSpec::quick("s3", 0.2)
        .with_trainer(TrainerKind::Torch)
        .with_impl(FetchImpl::Threaded);
    fixed.items = 160;
    rows.push(("threaded", fixed));

    for (tag, spec) in rows {
        let label = format!("{}/{}", spec.label(), tag);
        let (r, _) = rig::run(&spec)?;
        t.row(&[
            label,
            num(r.runtime_s, 2),
            num(r.img_per_s, 1),
            num(r.mbit_per_s, 1),
            num(r.util.util_zero_pct, 1),
        ]);
    }
    t.note("the threaded fetcher recovers most of the S3 penalty (paper: 15.5×)");
    println!("{}", t.render());
    Ok(())
}
