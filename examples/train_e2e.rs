//! End-to-end validation driver (DESIGN.md: deliverable (b)/§EXPERIMENTS):
//! train the real AOT-compiled JAX/Pallas ResNet for a few hundred steps
//! on a synthetic tiny-corpus through the full three-layer stack —
//!
//!   L3 rust ConcurrentDataloader (threaded fetcher, simulated S3)
//!     → PJRT transfer → L2/L1 fused train step (conv net + Pallas
//!       normalize & matmul kernels) → SGD update on-device
//!
//! and log the loss curve to `results/e2e/loss_curve.csv`.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example train_e2e
//! CDL_E2E_STEPS=300 cargo run --release --offline --example train_e2e
//! ```

use std::sync::Arc;

use cdl::data::synth::{generate_corpus, CorpusSpec};
use cdl::data::AugmentConfig;
use cdl::dataloader::{Dataloader, DataloaderConfig, FetchImpl};
use cdl::dataset::{Dataset, ImageFolderDataset};
use cdl::device::Device;
use cdl::runtime::XlaEngine;
use cdl::storage::{MemStore, ObjectStore, RemoteProfile, SimRemoteStore};
use cdl::telemetry::{names, Recorder};

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::var("CDL_E2E_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);
    let batch = 8usize;
    let image = 32usize;

    // L2/L1: the AOT-compiled model
    let engine = Arc::new(XlaEngine::start("artifacts").map_err(|e| {
        anyhow::anyhow!("{e}\nhint: run `make artifacts` first")
    })?);
    let variant = engine.manifest().train_variant(batch, image)?;
    println!(
        "model: {} params ({} classes), artifact {variant}",
        engine.manifest().num_params(),
        engine.manifest().num_classes()
    );
    engine.init_params()?;

    // corpus on simulated S3 (tiny-corpus: 512 images, so the model sees
    // each image ~several times across the run and the loss clearly drops)
    let backing: Arc<dyn ObjectStore> = Arc::new(MemStore::new("corpus"));
    generate_corpus(
        &backing,
        &CorpusSpec { items: 512, mean_bytes: 24 * 1024, ..Default::default() },
    )?;
    let store: Arc<dyn ObjectStore> =
        SimRemoteStore::new(backing, RemoteProfile::s3().scaled(0.05), 7);
    let dataset: Arc<dyn Dataset> = Arc::new(ImageFolderDataset::new(
        store,
        AugmentConfig { crop: image, ..Default::default() },
    ));

    let recorder = Recorder::new();
    let loader = Dataloader::new(
        dataset,
        DataloaderConfig {
            batch_size: batch,
            num_workers: 4,
            fetch_impl: FetchImpl::Threaded,
            num_fetch_workers: 16,
            drop_last: true,
            runtime: cdl::gil::Runtime::Native,
            spawn_cost_override: Some(std::time::Duration::from_millis(2)),
            ..Default::default()
        },
        recorder.clone(),
    );
    let device = Device::xla(engine, &variant, recorder.clone());

    // train
    let t0 = std::time::Instant::now();
    let mut losses: Vec<f32> = Vec::new();
    let mut epoch = 0usize;
    'outer: loop {
        for b in loader.epoch(epoch) {
            let db = device.to_device(b);
            let loss = device.train_batch(&db)?;
            losses.push(loss);
            if losses.len() % 20 == 0 {
                let last20: f32 =
                    losses[losses.len() - 20..].iter().sum::<f32>() / 20.0;
                println!(
                    "step {:>4}/{steps}  loss {loss:.4}  (mean-20 {last20:.4})",
                    losses.len()
                );
            }
            if losses.len() >= steps {
                break 'outer;
            }
        }
        epoch += 1;
    }
    let wall = t0.elapsed().as_secs_f64();

    // loss curve out
    std::fs::create_dir_all("results/e2e")?;
    let mut csv = String::from("step,loss\n");
    for (i, l) in losses.iter().enumerate() {
        csv.push_str(&format!("{i},{l}\n"));
    }
    std::fs::write("results/e2e/loss_curve.csv", csv)?;

    let first10: f32 = losses[..10].iter().sum::<f32>() / 10.0;
    let last10: f32 = losses[losses.len() - 10..].iter().sum::<f32>() / 10.0;
    println!("\n=== end-to-end validation ===");
    println!("steps:        {}", losses.len());
    println!("images:       {}", losses.len() * batch);
    println!("wall:         {wall:.1}s ({:.1} img/s)", (losses.len() * batch) as f64 / wall);
    println!("loss:         {first10:.3} (first-10 mean) → {last10:.3} (last-10 mean)");
    println!(
        "median spans: get_batch {} | to_device {} | train {}",
        cdl::util::fmt_duration(recorder.median(names::GET_BATCH)),
        cdl::util::fmt_duration(recorder.median(names::TO_DEVICE)),
        cdl::util::fmt_duration(recorder.median(names::TRAIN_BATCH)),
    );
    println!("loss curve:   results/e2e/loss_curve.csv");
    anyhow::ensure!(
        last10 < first10,
        "loss did not decrease ({first10:.3} → {last10:.3})"
    );
    println!("OK: loss decreased through the full three-layer stack");
    Ok(())
}
