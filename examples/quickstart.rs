//! Quickstart: build a small synthetic corpus on simulated S3, construct
//! the ConcurrentDataloader with the threaded fetcher, and iterate two
//! epochs — the 60-second tour of the public API.
//!
//! ```bash
//! cargo run --release --offline --example quickstart
//! ```

use std::sync::Arc;

use cdl::data::synth::{generate_corpus, CorpusSpec};
use cdl::data::AugmentConfig;
use cdl::dataloader::{Dataloader, DataloaderConfig, FetchImpl};
use cdl::dataset::{Dataset, ImageFolderDataset};
use cdl::storage::{MemStore, ObjectStore, RemoteProfile, SimRemoteStore};
use cdl::telemetry::Recorder;

fn main() -> anyhow::Result<()> {
    // 1. a synthetic ImageNet-like corpus (seeded, ~48 kB objects)
    let backing: Arc<dyn ObjectStore> = Arc::new(MemStore::new("corpus"));
    let (keys, bytes) = generate_corpus(
        &backing,
        &CorpusSpec { items: 256, mean_bytes: 48 * 1024, ..Default::default() },
    )?;
    println!("corpus: {} objects, {}", keys.len(), cdl::util::fmt_bytes(bytes));

    // 2. put it behind S3-like latency (scaled 4× down for the demo)
    let store: Arc<dyn ObjectStore> =
        SimRemoteStore::new(backing, RemoteProfile::s3().scaled(0.25), 42);

    // 3. Dataset with the paper's augmentation (crop to 64, flip;
    //    normalize runs on-device in the real pipeline)
    let dataset: Arc<dyn Dataset> = Arc::new(ImageFolderDataset::new(
        store,
        AugmentConfig { crop: 64, ..Default::default() },
    ));

    // 4. the ConcurrentDataloader: threaded fetcher, 4 workers × 16
    //    in-batch fetch threads — the paper's headline configuration
    let recorder = Recorder::new();
    let loader = Dataloader::new(
        dataset,
        DataloaderConfig {
            batch_size: 32,
            num_workers: 4,
            fetch_impl: FetchImpl::Threaded,
            num_fetch_workers: 16,
            ..Default::default()
        },
        recorder.clone(),
    );

    // 5. iterate
    for epoch in 0..2 {
        let t0 = std::time::Instant::now();
        let mut images = 0usize;
        let mut bytes = 0u64;
        for batch in loader.epoch(epoch) {
            images += batch.len();
            bytes += batch.raw_bytes;
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "epoch {epoch}: {images} images in {dt:.2}s — {:.1} img/s, {}",
            images as f64 / dt,
            cdl::util::fmt_mbit_s(bytes, dt),
        );
    }

    // 6. what did the time go into?
    println!("\n{}", recorder.summary_table("span medians").render());
    Ok(())
}
