//! Loader-architecture shoot-out (the paper's §A.5): per-item
//! ConcurrentDataloader vs WebDataset-style shard streaming vs
//! FastAI-style untar-then-local, all against the same S3-like storage.
//!
//! ```bash
//! cargo run --release --offline --example loaders_compare
//! ```

use std::sync::Arc;

use cdl::bench::rig::{self, RigSpec};
use cdl::data::synth::{generate_corpus, CorpusSpec};
use cdl::data::AugmentConfig;
use cdl::dataloader::FetchImpl;
use cdl::gil::Gil;
use cdl::shards::{build_shards, FastAiLoader, WebDatasetLoader};
use cdl::storage::{MemStore, ObjectStore, RemoteProfile, SimRemoteStore};
use cdl::util::table::{num, Table};

fn main() -> anyhow::Result<()> {
    let items = 128usize;
    let epochs = 3usize;
    let profile = RemoteProfile::s3().scaled(0.2);
    let aug = AugmentConfig { crop: 32, ..Default::default() };

    let corpus: Arc<dyn ObjectStore> = Arc::new(MemStore::new("c"));
    generate_corpus(
        &corpus,
        &CorpusSpec { items, mean_bytes: 48 * 1024, ..Default::default() },
    )?;

    let mut t = Table::new(
        "per-item concurrent vs shard loaders (s3-like storage)",
        &["loader", "setup s", "per-epoch s", "total s"],
    );

    // ours
    {
        let mut spec = RigSpec::quick("s3", 0.2).with_impl(FetchImpl::Threaded);
        spec.items = items;
        let rig = rig::build(&spec)?;
        let t0 = std::time::Instant::now();
        let mut per = Vec::new();
        for e in 0..epochs {
            let te = std::time::Instant::now();
            assert!(rig.dataloader.epoch(e).count() > 0);
            per.push(te.elapsed().as_secs_f64());
        }
        t.row(&[
            "concurrent (ours)".into(),
            "0.00".into(),
            num(per.iter().sum::<f64>() / per.len() as f64, 2),
            num(t0.elapsed().as_secs_f64(), 2),
        ]);
    }

    // webdataset streaming
    {
        let shards: Arc<dyn ObjectStore> = Arc::new(MemStore::new("s"));
        let keys = build_shards(&corpus, &shards, 2)?;
        let remote: Arc<dyn ObjectStore> = SimRemoteStore::new(shards, profile.clone(), 3);
        let wds = WebDatasetLoader::new(remote, keys, aug.clone());
        let gil = Gil::python();
        let t0 = std::time::Instant::now();
        let mut per = Vec::new();
        for e in 0..epochs {
            per.push(wds.epoch(e, &gil, |_| {})?.wall_secs);
        }
        t.row(&[
            "webdataset (stream)".into(),
            "0.00".into(),
            num(per.iter().sum::<f64>() / per.len() as f64, 2),
            num(t0.elapsed().as_secs_f64(), 2),
        ]);
    }

    // fastai untar
    {
        let shards: Arc<dyn ObjectStore> = Arc::new(MemStore::new("s2"));
        let keys = build_shards(&corpus, &shards, 1)?;
        let remote: Arc<dyn ObjectStore> = SimRemoteStore::new(shards, profile, 4);
        let t0 = std::time::Instant::now();
        let local: Arc<dyn ObjectStore> = Arc::new(MemStore::new("l"));
        let fa = FastAiLoader::untar_data(&remote, &keys, local, aug)?;
        let gil = Gil::python();
        let mut per = Vec::new();
        for e in 0..epochs {
            per.push(fa.epoch(e, &gil, |_| {})?.wall_secs);
        }
        t.row(&[
            "fastai (untar+local)".into(),
            num(fa.untar_secs, 2),
            num(per.iter().sum::<f64>() / per.len() as f64, 2),
            num(t0.elapsed().as_secs_f64(), 2),
        ]);
    }

    t.note("shards amortize the per-request RTT; per-item access pays it every object");
    println!("{}", t.render());
    Ok(())
}
